//! The training-method plugin API.
//!
//! The paper's contribution is a *method* — a post-step hook that
//! switches LoRA vectors while keeping optimizer state consistent — and
//! this module makes methods first-class plugins instead of special
//! cases inside the trainer: the leader loop in `coordinator/trainer.rs`
//! drives only the [`TrainingMethod`] trait, and every method (the
//! paper's SwitchLoRA, the full-rank / LoRA / ReLoRA / GaLore baselines,
//! the composable [`warmstart::WarmStart`] wrapper and the PreLoRA-style
//! layerwise hybrid) registers here by name.
//!
//! A method is configured by a [`Method`] spec — a registry name plus a
//! string option map (the CLI's `--interval0 40`-style flags land there
//! verbatim) — and instantiated through [`build`], which resolves the
//! name in [`registry`] and hands the factory a [`MethodCtx`] with the
//! manifest, total steps and seed.  The trait's hooks cover the whole
//! per-step surface: learning-rate adjustment, gradient masking, the
//! optimizer update itself (GaLore substitutes its host SVD optimizer
//! for the fused AdamW), the post-step mutation (switching, merge-and-
//! reset), named systems counters for the run report, and
//! `save_state`/`load_state` for mid-schedule checkpoint/resume.
//!
//! Adding a method means: implement the trait in a new submodule, add a
//! `MethodInfo` row to [`registry`] — nothing in the trainer changes.

pub mod full;
pub mod galore;
pub mod lora;
pub mod prelora;
pub mod relora;
pub mod switchlora;
pub mod warmstart;

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, ensure, Result};

pub use self::galore::GaloreParams;
pub use self::prelora::PreLoraParams;
pub use self::relora::ReLoraParams;
pub use self::switchlora::SwitchParams;

use crate::coordinator::trainer::TrainConfig;
use crate::model::layout::{Manifest, ParamStore, Variant};
use crate::optim::adam::AdamState;
use crate::optim::schedule::LrSchedule;
use crate::optim::AdamHyper;
use crate::runtime::{Engine, ModelRuntime};
use crate::util::rng::Rng;

/// Everything a method factory may consult when instantiating: the
/// (original) manifest, the run length and the run seed.
pub struct MethodCtx<'a> {
    /// the spec's manifest (layouts, linears, model config)
    pub manifest: &'a Manifest,
    /// total training steps (switch schedules are parameterized on it)
    pub steps: u64,
    /// run seed (methods derive their own independent streams from it)
    pub seed: u64,
}

/// A training method plugged into the leader loop.
///
/// The loop calls, in order per step: [`lr_adjust`](Self::lr_adjust) →
/// (gradients + all-reduce, method-agnostic) →
/// [`optim_step`](Self::optim_step) (whose default applies
/// [`grad_mask`](Self::grad_mask) and runs the fused AdamW) →
/// [`post_step`](Self::post_step).  Around the loop:
/// [`pre_run`](Self::pre_run) before step 0 (skipped on `--resume`), and
/// [`counters`](Self::counters) for the final report.  State that must
/// survive a kill-and-resume goes through
/// [`save_state`](Self::save_state) / [`load_state`](Self::load_state).
pub trait TrainingMethod {
    /// Registry name (plus configuration suffix for wrappers); matched
    /// against the checkpointed method state on resume.
    fn name(&self) -> &str;

    /// Which model variant's layout this method trains.
    fn variant(&self) -> Variant;

    /// Paper-default peak learning rate, used when the user sets none.
    fn default_lr(&self) -> f32;

    /// The manifest to train with.  Methods that rewrite layouts (the
    /// layerwise hybrid) return their own; `None` keeps the spec's.
    fn manifest(&self) -> Option<&Manifest> {
        None
    }

    /// Hook before step 0 — warm-start protocols run here.  Skipped
    /// entirely when the run resumes from a checkpoint (the checkpoint
    /// already contains the warm-started weights).
    fn pre_run(&mut self, _cfg: &TrainConfig, _manifest: &Manifest,
               _engine: &mut Engine, _store: &mut ParamStore)
        -> Result<()> {
        Ok(())
    }

    /// Adjust the scheduled learning rate for `step` (ReLoRA re-warms
    /// locally after each reset).
    fn lr_adjust(&self, _step: u64, lr: f32, _sched: &LrSchedule) -> f32 {
        lr
    }

    /// Zero mask lanes that must not update at `step` (freeze windows of
    /// freshly switched vectors).  May prune expired internal state.
    fn grad_mask(&mut self, _step: u64, _mask: &mut [f32]) {}

    /// The optimizer update for one step.  The default clones the base
    /// mask, applies [`grad_mask`](Self::grad_mask) and runs the fused
    /// AdamW over the packed trainable vector; methods that need host
    /// control between gradient and update (GaLore's SVD projection)
    /// override the whole hook.
    #[allow(clippy::too_many_arguments)]
    fn optim_step(&mut self, step: u64, rt: &ModelRuntime,
                  store: &mut ParamStore, grad: &[f32],
                  opt: &mut AdamState, base_mask: &[f32],
                  hyper: &AdamHyper) -> Result<()> {
        let mut mask = base_mask.to_vec();
        self.grad_mask(step, &mut mask);
        let mut flat = store.gather_trainable(rt.padded);
        rt.adam_step(&mut flat, grad, opt, &mask, hyper)?;
        store.scatter_trainable(&flat);
        Ok(())
    }

    /// Post-optimizer hook — the paper's Algorithm 2 switching, ReLoRA's
    /// merge-and-reset.  `rng` is the leader RNG (checkpointed with the
    /// trainer, so resumed draws continue the same stream).
    fn post_step(&mut self, _step: u64, _store: &mut ParamStore,
                 _opt: &mut AdamState, _rng: &mut Rng) -> Result<()> {
        Ok(())
    }

    /// Named systems counters for the run report (replaces the old
    /// hard-coded `offload_bytes`/`total_switches` result fields).
    fn counters(&self) -> Vec<(String, u64)> {
        Vec::new()
    }

    /// Serialize resumable state into `out`.  Stateless methods write
    /// nothing.
    fn save_state(&self, _out: &mut Vec<u8>) -> Result<()> {
        Ok(())
    }

    /// Restore state written by [`save_state`](Self::save_state).
    fn load_state(&mut self, bytes: &[u8]) -> Result<()> {
        ensure!(bytes.is_empty(),
                "method {:?} carries no resumable state but the \
                 checkpoint holds {} bytes of it", self.name(),
                bytes.len());
        Ok(())
    }

    /// Schema version of the `save_state` payload; bump on layout
    /// changes so stale checkpoints fail loudly.
    fn state_version(&self) -> u32 {
        1
    }
}

/// A method *specification*: registry name + string options.  This is
/// what lives in `TrainConfig`, what the CLI builds from `--method` and
/// the per-method flags, and what [`build`] instantiates.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Method {
    name: String,
    opts: BTreeMap<String, String>,
}

impl Method {
    /// A spec with no options (the method's defaults apply).
    pub fn new(name: impl Into<String>) -> Method {
        Method { name: name.into(), opts: BTreeMap::new() }
    }

    /// The registry name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Set an option (stringly, exactly as the CLI would).
    pub fn set(&mut self, key: &str, value: impl ToString) {
        self.opts.insert(key.to_string(), value.to_string());
    }

    /// Builder-style [`set`](Self::set).
    pub fn with(mut self, key: &str, value: impl ToString) -> Method {
        self.set(key, value);
        self
    }

    /// Raw option lookup.
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// Parse an option as a number, with a default when absent.
    pub fn opt_num<T: std::str::FromStr>(&self, key: &str, default: T)
        -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.opts.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("method option {key}={v:?}: {e}")),
        }
    }

    /// Parse a bare method name against the registry (defaults for every
    /// option).  Returns `None` for unknown names.
    pub fn parse(s: &str) -> Option<Method> {
        lookup(s).map(|info| Method::new(info.name))
    }

    /// The full-rank baseline.
    pub fn full() -> Method {
        Method::new("full")
    }

    /// The plain-LoRA baseline.
    pub fn lora() -> Method {
        Method::new("lora")
    }

    /// The paper's SwitchLoRA with explicit parameters.
    pub fn switchlora(p: SwitchParams) -> Method {
        Method::new("switchlora")
            .with("interval0", p.interval0)
            .with("ratio", p.ratio)
            .with("nfreeze", p.n_freeze)
    }

    /// The ReLoRA baseline with explicit parameters.
    pub fn relora(p: ReLoraParams) -> Method {
        Method::new("relora")
            .with("reset-interval", p.reset_interval)
            .with("rewarm", p.rewarm)
    }

    /// The GaLore baseline with explicit parameters.
    pub fn galore(p: GaloreParams) -> Method {
        Method::new("galore")
            .with("galore-rank", p.rank)
            .with("update-freq", p.update_freq)
            .with("galore-scale", p.scale)
    }

    /// The PreLoRA-style layerwise full+LoRA hybrid.
    pub fn prelora(p: PreLoraParams) -> Method {
        Method::new("prelora").with("full-layers", p.full_layers)
    }

    /// Wrap this spec in a full-rank warm start of `steps` steps (the
    /// Figure 4 protocol).  Wrapping a warm start only updates its
    /// length.
    pub fn warm_started(mut self, steps: u64) -> Method {
        if self.name == "warmstart" {
            self.set("warm-steps", steps);
            return self;
        }
        let mut m = Method { name: "warmstart".into(), opts: self.opts };
        m.set("inner", &self.name);
        m.set("warm-steps", steps);
        m
    }
}

type BuildFn = fn(&Method, &MethodCtx) -> Result<Box<dyn TrainingMethod>>;

/// One registry row: the name [`build`] resolves, a summary for
/// `switchlora info`, and the CLI option keys the method understands.
pub struct MethodInfo {
    /// registry name (`--method <name>`)
    pub name: &'static str,
    /// one-line description for help/info output
    pub summary: &'static str,
    /// CLI option keys copied from the arg map into the spec
    pub option_keys: &'static [&'static str],
    build: BuildFn,
}

static REGISTRY: &[MethodInfo] = &[
    MethodInfo {
        name: "full",
        summary: "full-rank AdamW baseline (paper lr 1e-3)",
        option_keys: &[],
        build: full::build,
    },
    MethodInfo {
        name: "lora",
        summary: "plain LoRA, fixed adapters (paper lr 1e-2)",
        option_keys: &[],
        build: lora::build,
    },
    MethodInfo {
        name: "switchlora",
        summary: "the paper's switched LoRA (Algorithms 1+2)",
        option_keys: &["interval0", "ratio", "nfreeze"],
        build: switchlora::build,
    },
    MethodInfo {
        name: "relora",
        summary: "ReLoRA merge-and-reset baseline (Lialin et al.)",
        option_keys: &["reset-interval", "rewarm"],
        build: relora::build,
    },
    MethodInfo {
        name: "galore",
        summary: "GaLore gradient low-rank projection (Zhao et al.)",
        option_keys: &["galore-rank", "update-freq", "galore-scale"],
        build: galore::build,
    },
    MethodInfo {
        name: "prelora",
        summary: "PreLoRA-style layerwise hybrid: first K layers \
                  full-rank, the rest LoRA",
        option_keys: &["full-layers"],
        build: prelora::build,
    },
    MethodInfo {
        name: "warmstart",
        summary: "composable full-rank warm start around any low-rank \
                  method (Figure 4 protocol)",
        option_keys: &["inner", "warm-steps"],
        build: warmstart::build,
    },
];

/// All registered methods, in registry order.
pub fn registry() -> &'static [MethodInfo] {
    REGISTRY
}

/// Look a method up by name.
pub fn lookup(name: &str) -> Option<&'static MethodInfo> {
    REGISTRY.iter().find(|m| m.name == name)
}

/// Comma-separated registry names (for error messages and help output).
pub fn known_names() -> String {
    REGISTRY
        .iter()
        .map(|m| m.name)
        .collect::<Vec<_>>()
        .join(", ")
}

/// Instantiate a method spec against a run context.
pub fn build(spec: &Method, ctx: &MethodCtx)
    -> Result<Box<dyn TrainingMethod>> {
    let info = lookup(spec.name()).ok_or_else(|| {
        anyhow!("unknown method {:?} (known: {})", spec.name(),
                known_names())
    })?;
    (info.build)(spec, ctx)
}

/// Build a method spec from parsed CLI args: `--method NAME` plus the
/// method's registered option keys (and, for wrappers that declare an
/// `inner` key, the inner method's keys as well).
pub fn from_args(args: &crate::cli::Args) -> Result<Method> {
    let name = args.get_or("method", "switchlora");
    let info = lookup(&name).ok_or_else(|| {
        anyhow!("unknown method {name:?} (known: {})", known_names())
    })?;
    let mut spec = Method::new(info.name);
    let mut keys: Vec<&'static str> = info.option_keys.to_vec();
    if info.option_keys.contains(&"inner") {
        let inner = args.get("inner").unwrap_or(warmstart::DEFAULT_INNER);
        match lookup(inner) {
            Some(ii) => keys.extend_from_slice(ii.option_keys),
            None => bail!("unknown inner method {inner:?} (known: {})",
                          known_names()),
        }
    }
    for key in keys {
        if let Some(v) = args.get(key) {
            spec.set(key, v);
        }
    }
    Ok(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_resolve() {
        for m in registry() {
            assert!(Method::parse(m.name).is_some(), "{}", m.name);
        }
        assert!(Method::parse("nope").is_none());
        assert!(known_names().contains("switchlora"));
    }

    #[test]
    fn builds_every_method_with_defaults() {
        let man = Manifest::builtin("tiny").unwrap();
        let ctx = MethodCtx { manifest: &man, steps: 100, seed: 1 };
        for info in registry() {
            let m = build(&Method::new(info.name), &ctx).unwrap();
            assert!(m.default_lr() > 0.0, "{}", info.name);
            // every method resolves to a real layout
            let manifest = m.manifest().unwrap_or(&man);
            assert!(manifest.layout(m.variant()).is_ok(), "{}",
                    info.name);
        }
    }

    #[test]
    fn typed_constructors_set_options() {
        let m = Method::switchlora(SwitchParams {
            interval0: 8.0, ratio: 0.5, n_freeze: 2,
        });
        assert_eq!(m.name(), "switchlora");
        assert_eq!(m.opt("interval0"), Some("8"));
        assert_eq!(m.opt_num("nfreeze", 0u64).unwrap(), 2);
        // absent key falls back to the default
        assert_eq!(m.opt_num("missing", 7u64).unwrap(), 7);
    }

    #[test]
    fn warm_start_wraps_and_rewraps() {
        let m = Method::switchlora(SwitchParams::default())
            .warm_started(50);
        assert_eq!(m.name(), "warmstart");
        assert_eq!(m.opt("inner"), Some("switchlora"));
        assert_eq!(m.opt("warm-steps"), Some("50"));
        // inner options survive the wrap
        assert_eq!(m.opt("interval0"), Some("40"));
        // re-wrapping only updates the length
        let m2 = m.warm_started(80);
        assert_eq!(m2.name(), "warmstart");
        assert_eq!(m2.opt("inner"), Some("switchlora"));
        assert_eq!(m2.opt("warm-steps"), Some("80"));
    }

    #[test]
    fn from_args_copies_registered_keys_only() {
        let args = crate::cli::Args::parse(
            "pretrain --method switchlora --interval0 9 --ratio 0.2 \
             --rewarm 33"
                .split_whitespace()
                .map(String::from),
        );
        let m = from_args(&args).unwrap();
        assert_eq!(m.opt("interval0"), Some("9"));
        assert_eq!(m.opt("ratio"), Some("0.2"));
        assert_eq!(m.opt("rewarm"), None); // not a switchlora key
        let bad = crate::cli::Args::parse(
            "pretrain --method bogus".split_whitespace().map(String::from));
        assert!(from_args(&bad).is_err());
    }
}
