//! `switchlora report TRACE.jsonl` — summarize a JSONL trace into the
//! per-phase / communication / switch-audit / memory tables.
//!
//! The reader is deliberately tolerant: unknown `kind`s are counted
//! but ignored, so traces from newer builds still summarize.  Chrome-
//! format traces are for Perfetto — `summarize` detects them and bails
//! with a pointer rather than mis-parsing.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::{human_bytes, human_bytes_f64};
use crate::util::json::Json;

/// Canonical trainer phases, in step order.  `trace_check.py` and the
/// phase-coverage test key off this list.
pub const PHASES: [&str; 8] = ["data", "forward", "backward", "allreduce",
                               "optim", "switch", "eval", "checkpoint"];

#[derive(Clone, Debug, Default)]
pub struct SpanAgg {
    pub cat: String,
    pub count: u64,
    pub total_us: u64,
    pub max_us: u64,
}

/// One memory-ledger row as read back from the trace.
#[derive(Clone, Debug)]
pub struct MemRowRead {
    pub component: String,
    pub dtype: String,
    pub bytes: u64,
}

#[derive(Clone, Debug, Default)]
pub struct Report {
    pub events: u64,
    /// span name -> aggregate (across all cats; phase spans keep their
    /// bare name, the canonical eight never collide with other cats)
    pub spans: BTreeMap<String, SpanAgg>,
    pub comm_rounds: u64,
    pub comm_round_bytes: u64,
    pub switches: u64,
    pub switch_by_layer: BTreeMap<String, u64>,
    pub switch_steps: Option<(u64, u64)>,
    /// context -> (rows, total) — last event per context wins
    pub memory: BTreeMap<String, (Vec<MemRowRead>, u64)>,
    pub counters: BTreeMap<String, u64>,
    pub hists: Vec<(String, u64, f64)>,
    pub summary_steps: Option<u64>,
    pub summary_comm_bytes: Option<u64>,
    pub summary_comm_rounds: Option<u64>,
    pub summary_elapsed_us: Option<u64>,
    pub kv_peak_used: u64,
    pub kv_peak_bytes: u64,
}

fn num_u64(j: &Json, key: &str) -> Result<u64> {
    Ok(j.get(key)?.as_f64()? as u64)
}

pub fn summarize(path: &Path) -> Result<Report> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    if text.trim_start().starts_with('[') {
        bail!("{} looks like a chrome-format trace (load it in Perfetto \
               or chrome://tracing); `report` reads the JSONL format — \
               re-run with `--trace-format jsonl`",
              path.display());
    }
    let mut r = Report::default();
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line)
            .with_context(|| format!("{}:{}", path.display(), ln + 1))?;
        r.events += 1;
        let kind = j.get("kind")?.as_str()?.to_string();
        match kind.as_str() {
            "span" => {
                let name = j.get("name")?.as_str()?.to_string();
                let cat = j.get("cat")?.as_str()?.to_string();
                let dur = num_u64(&j, "dur")?;
                let agg = r.spans.entry(name).or_default();
                agg.cat = cat;
                agg.count += 1;
                agg.total_us += dur;
                agg.max_us = agg.max_us.max(dur);
            }
            "comm.round" => {
                r.comm_rounds += 1;
                r.comm_round_bytes += num_u64(&j, "bytes")?;
            }
            "switch" => {
                r.switches += 1;
                let layer = j.get("layer")?.as_str()?.to_string();
                *r.switch_by_layer.entry(layer).or_insert(0) += 1;
                let step = num_u64(&j, "step")?;
                r.switch_steps = Some(match r.switch_steps {
                    None => (step, step),
                    Some((lo, hi)) => (lo.min(step), hi.max(step)),
                });
            }
            "memory" => {
                let ctx = j.get("context")?.as_str()?.to_string();
                let mut rows = Vec::new();
                for row in j.get("rows")?.as_arr()? {
                    rows.push(MemRowRead {
                        component: row.get("component")?
                                      .as_str()?
                                      .to_string(),
                        dtype: row.get("dtype")?.as_str()?.to_string(),
                        bytes: num_u64(row, "bytes")?,
                    });
                }
                let total = num_u64(&j, "total")?;
                r.memory.insert(ctx, (rows, total));
            }
            "kv" => {
                r.kv_peak_used = r.kv_peak_used.max(num_u64(&j, "used")?);
                r.kv_peak_bytes =
                    r.kv_peak_bytes.max(num_u64(&j, "bytes")?);
            }
            "counters" => {
                if let Json::Obj(m) = j.get("values")? {
                    for (k, v) in m {
                        r.counters.insert(k.clone(), v.as_f64()? as u64);
                    }
                }
            }
            "hist" => {
                r.hists.push((j.get("name")?.as_str()?.to_string(),
                              num_u64(&j, "count")?,
                              j.get("sum")?.as_f64()?));
            }
            "run_summary" => {
                r.summary_steps = Some(num_u64(&j, "steps")?);
                r.summary_comm_bytes = Some(num_u64(&j, "comm_bytes")?);
                r.summary_comm_rounds = Some(num_u64(&j, "comm_rounds")?);
                r.summary_elapsed_us = Some(num_u64(&j, "elapsed_us")?);
            }
            // unknown kinds: tolerated for forward compatibility
            _ => {}
        }
    }
    Ok(r)
}

impl Report {
    pub fn render(&self) -> String {
        let mut out = String::new();
        let mut line = |s: String| {
            out.push_str(&s);
            out.push('\n');
        };
        line(format!("trace summary: {} events", self.events));

        // -- per-phase step profile --
        line(String::new());
        line("per-phase step profile".to_string());
        line(format!("  {:<12} {:>7} {:>12} {:>10} {:>10}",
                     "phase", "calls", "total(ms)", "mean(ms)",
                     "max(ms)"));
        let mut shown: Vec<&str> = Vec::new();
        for ph in PHASES {
            if self.spans.contains_key(ph) {
                shown.push(ph);
            }
        }
        let phase_total: u64 =
            shown.iter().map(|p| self.spans[*p].total_us).sum();
        for &ph in &shown {
            let a = &self.spans[ph];
            line(format!(
                "  {:<12} {:>7} {:>12.1} {:>10.3} {:>10.3}",
                ph, a.count, a.total_us as f64 / 1e3,
                a.total_us as f64 / 1e3 / a.count.max(1) as f64,
                a.max_us as f64 / 1e3));
        }
        if phase_total > 0 {
            line(format!("  phase wall total {:.1} ms",
                         phase_total as f64 / 1e3));
        }
        let others: Vec<_> = self.spans
                                .iter()
                                .filter(|(n, _)| {
                                    !PHASES.contains(&n.as_str())
                                })
                                .collect();
        if !others.is_empty() {
            line(String::new());
            line("other spans".to_string());
            for (name, a) in others {
                line(format!(
                    "  {:<20} {:>7} calls {:>12.1} ms total ({})",
                    format!("{}:{}", a.cat, name), a.count,
                    a.total_us as f64 / 1e3, a.cat));
            }
        }

        // -- communication --
        line(String::new());
        line("communication".to_string());
        line(format!("  {} rounds, {} on the wire",
                     self.comm_rounds,
                     human_bytes(self.comm_round_bytes)));
        if let Some(total) = self.summary_comm_bytes {
            let ok = total == self.comm_round_bytes;
            line(format!(
                "  ledger cross-check: run summary {} — {}",
                human_bytes(total),
                if ok { "match" } else { "MISMATCH" }));
        }
        if let (Some(steps), true) =
            (self.summary_steps, self.comm_rounds > 0)
        {
            if steps > 0 {
                line(format!(
                    "  {}/step",
                    human_bytes_f64(self.comm_round_bytes as f64
                                    / steps as f64)));
            }
        }

        // -- switch audit --
        if self.switches > 0 {
            line(String::new());
            line("switch audit".to_string());
            let (lo, hi) = self.switch_steps.unwrap_or((0, 0));
            line(format!("  {} switches over steps {lo}..={hi}",
                         self.switches));
            for (layer, n) in &self.switch_by_layer {
                line(format!("  {:<24} {:>6}", layer, n));
            }
        }

        // -- memory ledgers --
        for (ctx, (rows, total)) in &self.memory {
            line(String::new());
            line(format!("memory ledger [{ctx}]"));
            line(format!("  {:<20} {:>6} {:>12}",
                         "component", "dtype", "bytes"));
            for row in rows {
                line(format!("  {:<20} {:>6} {:>12}",
                             row.component, row.dtype,
                             human_bytes(row.bytes)));
            }
            line(format!("  {:<20} {:>6} {:>12}",
                         "total", "", human_bytes(*total)));
        }
        if self.kv_peak_bytes > 0 {
            line(format!("  kv cache peak: {} used rows, {}",
                         self.kv_peak_used,
                         human_bytes(self.kv_peak_bytes)));
        }

        // -- counters / histograms --
        if !self.counters.is_empty() {
            line(String::new());
            line("counters".to_string());
            for (k, v) in &self.counters {
                line(format!("  {k:<24} {v:>12}"));
            }
        }
        if !self.hists.is_empty() {
            line(String::new());
            line("histograms".to_string());
            for (name, count, sum) in &self.hists {
                let mean = if *count == 0 {
                    0.0
                } else {
                    sum / *count as f64
                };
                line(format!("  {name:<24} n={count} mean={mean:.1}"));
            }
        }
        out
    }
}
