//! Streaming histograms with **fixed** bucket edges.
//!
//! The edges are chosen up front and never move, so the dumped counts
//! are a deterministic function of the recorded values: two runs that
//! record the same multiset of values — in any order, from any number
//! of threads — serialize identical histograms.  (Adaptive/quantile
//! sketches trade that away for accuracy we don't need here.)

/// A fixed-edge histogram.  `counts.len() == edges.len() + 1`: bucket
/// `0` is the underflow bucket `(-inf, edges[0])`, bucket `i` covers
/// `[edges[i-1], edges[i])`, and the last bucket is the overflow
/// `[edges.last(), +inf)`.
#[derive(Clone, Debug, PartialEq)]
pub struct Hist {
    pub edges: Vec<f64>,
    pub counts: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

impl Hist {
    pub fn new(edges: Vec<f64>) -> Hist {
        assert!(edges.windows(2).all(|w| w[0] < w[1]),
                "histogram edges must be strictly ascending");
        let n = edges.len() + 1;
        Hist { edges, counts: vec![0; n], count: 0, sum: 0.0 }
    }

    /// Default edges for microsecond latencies: a 1-2-5 ladder from
    /// 1 µs to 10 s (22 edges, 23 buckets).
    pub fn latency_us() -> Hist {
        let mut edges = Vec::new();
        let mut decade = 1.0f64;
        while decade < 1e7 {
            for m in [1.0, 2.0, 5.0] {
                edges.push(decade * m);
            }
            decade *= 10.0;
        }
        edges.push(1e7);
        Hist::new(edges)
    }

    /// Bucket index for `value`: the number of edges `<= value`.
    pub fn bucket(&self, value: f64) -> usize {
        self.edges.partition_point(|&e| e <= value)
    }

    pub fn record(&mut self, value: f64) {
        let b = self.bucket(value);
        self.counts[b] += 1;
        self.count += 1;
        self.sum += value;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.sum / self.count as f64 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucketing_is_deterministic_and_order_invariant() {
        let mut a = Hist::new(vec![1.0, 10.0, 100.0]);
        let mut b = Hist::new(vec![1.0, 10.0, 100.0]);
        let vals = [0.5, 1.0, 5.0, 99.9, 100.0, 1e6];
        for &v in &vals {
            a.record(v);
        }
        for &v in vals.iter().rev() {
            b.record(v);
        }
        assert_eq!(a.counts, b.counts);
        // 0.5 underflows; 1.0 and 5.0 land in [1,10); 99.9 in
        // [10,100); 100.0 and 1e6 overflow into [100, inf)
        assert_eq!(a.counts, vec![1, 2, 1, 2]);
        assert_eq!(a.count, 6);
        assert_eq!(a.bucket(0.0), 0);
        assert_eq!(a.bucket(1.0), 1);
        assert_eq!(a.bucket(100.0), 3);
    }

    #[test]
    fn latency_edges_are_strictly_ascending() {
        let h = Hist::latency_us();
        assert!(h.edges.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(h.counts.len(), h.edges.len() + 1);
        assert_eq!(h.edges[0], 1.0);
        assert_eq!(*h.edges.last().unwrap(), 1e7);
    }

    #[test]
    fn mean_tracks_sum() {
        let mut h = Hist::new(vec![10.0]);
        assert_eq!(h.mean(), 0.0);
        h.record(4.0);
        h.record(8.0);
        assert_eq!(h.mean(), 6.0);
        assert_eq!(h.sum, 12.0);
    }
}
