//! Trace sinks: where telemetry events go once serialized.
//!
//! Two formats share one writer:
//!
//! * **JSONL** (`--trace-out run.jsonl`) — one JSON object per line,
//!   self-describing via a `kind` field.  This is the machine format:
//!   `switchlora report` and `tools/trace_check.py` consume it.
//! * **Chrome trace-event** (`--trace-format chrome`) — a JSON array of
//!   `ph:"X"` duration events and `ph:"i"` instants, loadable directly
//!   in Perfetto or `chrome://tracing`.
//!
//! Mid-run IO errors are swallowed (tracing must never abort a run);
//! they surface once, from [`TraceSink::finish`]'s flush.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceFormat {
    Jsonl,
    Chrome,
}

impl TraceFormat {
    pub fn parse(s: &str) -> Result<TraceFormat> {
        Ok(match s {
            "jsonl" => TraceFormat::Jsonl,
            "chrome" => TraceFormat::Chrome,
            other => bail!("--trace-format must be jsonl or chrome, \
                            got {other:?}"),
        })
    }
}

pub struct TraceSink {
    out: BufWriter<File>,
    pub format: TraceFormat,
    /// Trace epoch: every `ts` is microseconds since this instant.
    pub start: Instant,
    wrote_any: bool,
    pub events: u64,
}

impl TraceSink {
    pub fn open(path: &Path, format: TraceFormat) -> Result<TraceSink> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).with_context(
                    || format!("creating trace dir {}", dir.display()))?;
            }
        }
        let f = File::create(path)
            .with_context(|| format!("creating trace {}", path.display()))?;
        let mut out = BufWriter::new(f);
        if format == TraceFormat::Chrome {
            let _ = out.write_all(b"[\n");
        }
        Ok(TraceSink {
            out,
            format,
            start: Instant::now(),
            wrote_any: false,
            events: 0,
        })
    }

    /// Microseconds of `t` relative to the trace epoch (0 if earlier).
    pub fn rel_us(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.start).as_micros() as u64
    }

    pub fn now_us(&self) -> u64 {
        self.start.elapsed().as_micros() as u64
    }

    fn emit(&mut self, j: Json) {
        let line = j.to_string();
        if self.format == TraceFormat::Chrome {
            if self.wrote_any {
                let _ = self.out.write_all(b",\n");
            }
            let _ = self.out.write_all(line.as_bytes());
        } else {
            let _ = self.out.write_all(line.as_bytes());
            let _ = self.out.write_all(b"\n");
        }
        self.wrote_any = true;
        self.events += 1;
    }

    /// A completed duration span.
    pub fn span(&mut self, cat: &str, name: &str, ts_us: u64, dur_us: u64,
                tid: u64) {
        let j = match self.format {
            TraceFormat::Jsonl => Json::obj(vec![
                ("kind", Json::str("span")),
                ("cat", Json::str(cat)),
                ("name", Json::str(name)),
                ("ts", Json::num(ts_us as f64)),
                ("dur", Json::num(dur_us as f64)),
                ("tid", Json::num(tid as f64)),
            ]),
            TraceFormat::Chrome => Json::obj(vec![
                ("name", Json::str(name)),
                ("cat", Json::str(cat)),
                ("ph", Json::str("X")),
                ("ts", Json::num(ts_us as f64)),
                ("dur", Json::num(dur_us as f64)),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(tid as f64)),
            ]),
        };
        self.emit(j);
    }

    /// A typed instant event with free-form payload fields.  In JSONL
    /// the fields live at the top level next to `kind`/`ts`/`tid`; in
    /// Chrome format they become the instant's `args`.
    pub fn event(&mut self, kind: &str, ts_us: u64, tid: u64,
                 fields: Vec<(&str, Json)>) {
        let j = match self.format {
            TraceFormat::Jsonl => {
                let mut pairs = vec![
                    ("kind", Json::str(kind)),
                    ("ts", Json::num(ts_us as f64)),
                    ("tid", Json::num(tid as f64)),
                ];
                pairs.extend(fields);
                Json::obj(pairs)
            }
            TraceFormat::Chrome => Json::obj(vec![
                ("name", Json::str(kind)),
                ("cat", Json::str("event")),
                ("ph", Json::str("i")),
                ("ts", Json::num(ts_us as f64)),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(tid as f64)),
                ("s", Json::str("t")),
                ("args", Json::obj(fields)),
            ]),
        };
        self.emit(j);
    }

    /// Close the chrome array (if any) and flush.  The one place IO
    /// errors are reported.
    pub fn finish(&mut self) -> Result<()> {
        if self.format == TraceFormat::Chrome {
            self.out.write_all(b"\n]\n").context("closing trace")?;
        }
        self.out.flush().context("flushing trace")?;
        Ok(())
    }
}
