//! Telemetry subsystem: metrics registry, thread-aware span timers,
//! structured trace sinks, typed audit events and memory ledgers.
//!
//! Design rules, in order:
//!
//! 1. **Never touch math or RNG.**  Instrumentation reads clocks and
//!    writes bytes; it must not perturb a single bit of the run.  The
//!    contract is pinned by `tests/observability.rs`, which compares a
//!    traced and an untraced run bitwise (losses, final weights, comm
//!    bytes).
//! 2. **Near-zero cost when disabled.**  Every emitter starts with one
//!    relaxed atomic load; spans additionally take one `Instant::now`
//!    so their wall-clock reading stays available to callers (the
//!    trainer heartbeat uses it) even with tracing off.
//! 3. **Deterministic output shape.**  Histograms use fixed bucket
//!    edges ([`hist`]), JSON objects serialize with sorted keys, and
//!    counters dump in name order — only timestamps and durations vary
//!    between runs.
//!
//! State is process-global (like the kernel pool's thread setting):
//! `enable()` opens a sink, instrumented code emits through it, and
//! `finish()` dumps the registries and flushes.  Span/instant events
//! carry a small process-local thread id so shard fan-out in
//! `kernels::scoped_map` shows up as parallel tracks in Perfetto.

pub mod hist;
pub mod report;
pub mod sink;

use std::cell::Cell;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

use anyhow::Result;

use crate::infer::kv_cache::KvCache;
use crate::model::packed::PackedStore;
use crate::tensor::dtype::DType;
use crate::util::json::Json;

pub use hist::Hist;
pub use sink::{TraceFormat, TraceSink};

// ---------------------------------------------------------------------
// Global state
// ---------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<TraceSink>> = Mutex::new(None);
static COUNTERS: Mutex<BTreeMap<String, u64>> = Mutex::new(BTreeMap::new());
static GAUGES: Mutex<BTreeMap<String, f64>> = Mutex::new(BTreeMap::new());
static HISTS: Mutex<BTreeMap<String, Hist>> = Mutex::new(BTreeMap::new());

// Kernel-pool utilization tallies.  `pool::run` is called once per
// kernel invocation — far too hot for a map lookup under a mutex, so
// these get dedicated atomics and fold into the counter dump at
// `finish()`.
static POOL_JOBS: AtomicU64 = AtomicU64::new(0);
static POOL_TASKS: AtomicU64 = AtomicU64::new(0);
static POOL_INLINE_JOBS: AtomicU64 = AtomicU64::new(0);
static POOL_INLINE_TASKS: AtomicU64 = AtomicU64::new(0);

static NEXT_TID: AtomicU64 = AtomicU64::new(1);
thread_local! {
    static TID: Cell<u64> = const { Cell::new(0) };
}

/// Small process-local thread id (1, 2, …) — stable per thread,
/// assigned on first telemetry emission from that thread.
fn tid() -> u64 {
    TID.with(|c| {
        let t = c.get();
        if t != 0 {
            return t;
        }
        let t = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        c.set(t);
        t
    })
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Open a trace sink and reset the registries.  Process-global; a
/// second `enable` replaces the previous sink without flushing it —
/// call [`finish`] first.
pub fn enable(path: &Path, format: TraceFormat) -> Result<()> {
    let sink = TraceSink::open(path, format)?;
    lock(&COUNTERS).clear();
    lock(&GAUGES).clear();
    lock(&HISTS).clear();
    for c in [&POOL_JOBS, &POOL_TASKS, &POOL_INLINE_JOBS,
              &POOL_INLINE_TASKS]
    {
        c.store(0, Ordering::Relaxed);
    }
    *lock(&SINK) = Some(sink);
    ENABLED.store(true, Ordering::SeqCst);
    Ok(())
}

/// Dump the counter/gauge/histogram registries as final events, close
/// the sink, and disable tracing.  No-op when tracing is off.
pub fn finish() -> Result<()> {
    if !ENABLED.swap(false, Ordering::SeqCst) {
        return Ok(());
    }
    let mut g = lock(&SINK);
    let Some(mut s) = g.take() else { return Ok(()) };
    let mut counters = lock(&COUNTERS).clone();
    for (name, c) in [("pool.jobs", &POOL_JOBS),
                      ("pool.tasks", &POOL_TASKS),
                      ("pool.inline_jobs", &POOL_INLINE_JOBS),
                      ("pool.inline_tasks", &POOL_INLINE_TASKS)]
    {
        let v = c.load(Ordering::Relaxed);
        if v > 0 {
            counters.insert(name.to_string(), v);
        }
    }
    let ts = s.now_us();
    let t = tid();
    let vals: Vec<(&str, Json)> = counters
        .iter()
        .map(|(k, &v)| (k.as_str(), Json::num(v as f64)))
        .collect();
    s.event("counters", ts, t, vec![("values", Json::obj(vals))]);
    let gauges = lock(&GAUGES).clone();
    if !gauges.is_empty() {
        let vals: Vec<(&str, Json)> = gauges
            .iter()
            .map(|(k, &v)| (k.as_str(), Json::num(v)))
            .collect();
        s.event("gauges", ts, t, vec![("values", Json::obj(vals))]);
    }
    for (name, h) in lock(&HISTS).iter() {
        s.event("hist", ts, t, vec![
            ("name", Json::str(name)),
            ("edges",
             Json::Arr(h.edges.iter().map(|&e| Json::num(e)).collect())),
            ("counts",
             Json::Arr(h.counts.iter()
                               .map(|&c| Json::num(c as f64))
                               .collect())),
            ("count", Json::num(h.count as f64)),
            ("sum", Json::num(h.sum)),
        ]);
    }
    s.finish()
}

// ---------------------------------------------------------------------
// Spans
// ---------------------------------------------------------------------

/// A live span timer.  Dropping it records the span; `done()` records
/// it and returns the elapsed seconds (valid with tracing off too —
/// the clock read always happens, only the event write is gated).
pub struct Span {
    start: Instant,
    cat: &'static str,
    name: &'static str,
    live: bool,
}

pub fn span(cat: &'static str, name: &'static str) -> Span {
    Span { start: Instant::now(), cat, name, live: true }
}

/// A trainer-phase span (`cat = "phase"`): one of the eight step
/// phases `report` aggregates (see [`report::PHASES`]).
pub fn phase(name: &'static str) -> Span {
    span("phase", name)
}

impl Span {
    /// Record the span now (instead of at drop) and return its
    /// duration in seconds.
    pub fn done(mut self) -> f64 {
        self.live = false;
        record_span(self.cat, self.name, self.start)
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.live {
            record_span(self.cat, self.name, self.start);
        }
    }
}

fn record_span(cat: &'static str, name: &'static str, start: Instant)
               -> f64 {
    let secs = start.elapsed().as_secs_f64();
    if enabled() {
        let mut g = lock(&SINK);
        if let Some(s) = g.as_mut() {
            let ts = s.rel_us(start);
            s.span(cat, name, ts, (secs * 1e6) as u64, tid());
        }
    }
    secs
}

// ---------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------

/// Bump a named counter (dumped in the final `counters` event).
pub fn add(name: &str, delta: u64) {
    if !enabled() {
        return;
    }
    *lock(&COUNTERS).entry(name.to_string()).or_insert(0) += delta;
}

/// Set a named gauge to its latest value.
pub fn gauge(name: &str, value: f64) {
    if !enabled() {
        return;
    }
    lock(&GAUGES).insert(name.to_string(), value);
}

/// Record into a named histogram (created with the default
/// microsecond-latency edges on first use).
pub fn hist_record(name: &str, value: f64) {
    if !enabled() {
        return;
    }
    lock(&HISTS)
        .entry(name.to_string())
        .or_insert_with(Hist::latency_us)
        .record(value);
}

/// Emit a typed instant event with free-form payload fields.
pub fn event(kind: &str, fields: Vec<(&str, Json)>) {
    if !enabled() {
        return;
    }
    let mut g = lock(&SINK);
    if let Some(s) = g.as_mut() {
        let ts = s.now_us();
        s.event(kind, ts, tid(), fields);
    }
}

/// Tally one `kernels::pool::run` call (hot path — atomics only).
pub(crate) fn pool_tally(n_tasks: usize, pooled: bool) {
    if !enabled() {
        return;
    }
    if pooled {
        POOL_JOBS.fetch_add(1, Ordering::Relaxed);
        POOL_TASKS.fetch_add(n_tasks as u64, Ordering::Relaxed);
    } else {
        POOL_INLINE_JOBS.fetch_add(1, Ordering::Relaxed);
        POOL_INLINE_TASKS.fetch_add(n_tasks as u64, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------
// Typed emitters
// ---------------------------------------------------------------------

/// Audit-trail record for one adapter-vector switch (one application
/// of the paper's Algorithm 1 to one slot).  `side` is `"b"` (a column
/// of B, length `len = out_features`) or `"a"` (a row of A, length
/// `len = in_features`); `slot` is the adapter rank index swapped,
/// `pool_slot` the candidate-pool column it exchanged with,
/// `pool_next` the pool's LRU cursor after the swap, and
/// `freeze_until` the step before which the counterpart's optimizer
/// state stays zeroed.
#[allow(clippy::too_many_arguments)]
pub fn switch_event(step: u64, layer: &str, side: &str, slot: usize,
                    pool_slot: usize, len: usize, pool_size: usize,
                    pool_next: usize, freeze_until: u64) {
    if !enabled() {
        return;
    }
    add("switch.events", 1);
    event("switch", vec![
        ("step", Json::num(step as f64)),
        ("layer", Json::str(layer)),
        ("side", Json::str(side)),
        ("slot", Json::num(slot as f64)),
        ("pool_slot", Json::num(pool_slot as f64)),
        ("len", Json::num(len as f64)),
        ("pool_size", Json::num(pool_size as f64)),
        ("pool_next", Json::num(pool_next as f64)),
        ("freeze_until", Json::num(freeze_until as f64)),
    ]);
}

/// One ring all-reduce invocation: the measured wire traffic.
pub fn comm_round(bytes: u64, elems: usize, workers: usize, wire: DType) {
    if !enabled() {
        return;
    }
    add("comm.bytes", bytes);
    add("comm.rounds", 1);
    event("comm.round", vec![
        ("bytes", Json::num(bytes as f64)),
        ("elems", Json::num(elems as f64)),
        ("workers", Json::num(workers as f64)),
        ("wire", Json::str(wire.name())),
    ]);
}

/// End-of-run summary: the cross-check anchor `report` reconciles the
/// summed `comm.round` events against.
pub fn run_summary(steps: u64, comm_bytes: u64, comm_rounds: u64,
                   elapsed_secs: f64) {
    if !enabled() {
        return;
    }
    event("run_summary", vec![
        ("steps", Json::num(steps as f64)),
        ("comm_bytes", Json::num(comm_bytes as f64)),
        ("comm_rounds", Json::num(comm_rounds as f64)),
        ("elapsed_us", Json::num((elapsed_secs * 1e6).round())),
    ]);
}

// ---------------------------------------------------------------------
// Memory ledger
// ---------------------------------------------------------------------

/// One memory-ledger row: a resident-byte component at its storage
/// dtype.  `component` is an owned string so multi-tenant contexts can
/// emit one row per named adapter (`adapter:<name>`).
#[derive(Clone, Debug, PartialEq)]
pub struct MemRow {
    pub component: String,
    pub dtype: DType,
    pub bytes: u64,
}

pub fn mem_total(rows: &[MemRow]) -> u64 {
    rows.iter().map(|r| r.bytes).sum()
}

/// Training-resident decomposition: the f32 master store split into
/// frozen base and trainable adapter params, the Adam moment buffers
/// (m/v/s, kept f32 in RAM regardless of checkpoint dtype), and the
/// bf16-accounted candidate pools when the method keeps them
/// (`pool_bytes` from the method's `pool_resident_bytes` counter).
pub fn train_mem_rows(total: usize, n_trainable: usize, padded: usize,
                      pool_bytes: u64) -> Vec<MemRow> {
    let mut rows = vec![
        MemRow { component: "master".to_string(),
                 dtype: DType::F32,
                 bytes: 4 * (total - n_trainable) as u64 },
        MemRow { component: "adapter".to_string(),
                 dtype: DType::F32,
                 bytes: 4 * n_trainable as u64 },
        MemRow { component: "optimizer_moments".to_string(),
                 dtype: DType::F32,
                 bytes: 3 * 4 * padded as u64 },
    ];
    if pool_bytes > 0 {
        rows.push(MemRow { component: "candidate_pool".to_string(),
                           dtype: DType::Bf16,
                           bytes: pool_bytes });
    }
    rows
}

/// Serving decomposition of a [`PackedStore`]: base weights at the
/// packed dtype (scale overhead included), everything else f32.  The
/// row total equals `PackedStore::resident_bytes()` exactly
/// (test-pinned).
pub fn packed_mem_rows(p: &PackedStore, base_dtype: DType) -> Vec<MemRow> {
    let (base_packed, _base_f32) = p.base_bytes();
    let rest = p.resident_bytes() - base_packed;
    vec![
        MemRow { component: "frozen_base".to_string(),
                 dtype: base_dtype,
                 bytes: base_packed as u64 },
        MemRow { component: "serve_master".to_string(),
                 dtype: DType::F32,
                 bytes: rest as u64 },
    ]
}

/// The KV-cache row; equals `KvCache::bytes()` exactly (test-pinned).
/// Under the paged allocator that is the *allocated block pool* — it
/// grows with the live-token high-water mark, not the `batch ×
/// capacity` slab the pre-paging cache reserved — and the serving
/// scheduler pairs it with `serve.kv_blocks_live` / `serve.kv_blocks_free`
/// gauges for the block-level view.
pub fn kv_mem_row(cache: &KvCache) -> MemRow {
    MemRow { component: "kv_cache".to_string(),
             dtype: cache.dtype(),
             bytes: cache.bytes() as u64 }
}

/// KV decomposition with prefix sharing: the `kv_cache` row carries the
/// blocks owned by live sequences plus shared sealed blocks, and a
/// `kv_prefix_pool` row (present only when nonzero) carries the sealed
/// blocks parked in the LRU prefix pool awaiting reuse.  The two rows
/// sum to `KvCache::bytes()` exactly, so the ledger total is unchanged
/// by sharing — the pool is retained memory, not new memory.
pub fn kv_mem_rows(cache: &KvCache) -> Vec<MemRow> {
    let pool = cache.prefix_pool_bytes() as u64;
    let mut rows = vec![
        MemRow { component: "kv_cache".to_string(),
                 dtype: cache.dtype(),
                 bytes: cache.bytes() as u64 - pool },
    ];
    if pool > 0 {
        rows.push(MemRow { component: "kv_prefix_pool".to_string(),
                           dtype: cache.dtype(),
                           bytes: pool });
    }
    rows
}

/// Multi-tenant serving decomposition: the ONE shared packed base (the
/// [`packed_mem_rows`] rows — their subtotal still equals
/// `PackedStore::resident_bytes()` exactly), one `adapter:<name>` row
/// per resident adapter's f32 factors (`(name, bytes)` pairs, from
/// `AdapterSet::resident_bytes`), and the KV cache.  Adding a tenant
/// adds one small adapter row while the base rows stay byte-identical —
/// the zero-base-duplication claim, ledger-verified in
/// `rust/tests/serving.rs`.
pub fn serve_mem_rows(p: &PackedStore, base_dtype: DType,
                      adapters: &[(String, u64)], cache: &KvCache)
    -> Vec<MemRow> {
    let mut rows = packed_mem_rows(p, base_dtype);
    for (name, bytes) in adapters {
        rows.push(MemRow { component: format!("adapter:{name}"),
                           dtype: DType::F32,
                           bytes: *bytes });
    }
    rows.extend(kv_mem_rows(cache));
    rows
}

/// Emit a memory-ledger event: dtype-decomposed resident bytes for one
/// context ("train", "serve", …).
pub fn memory_event(context: &str, rows: &[MemRow]) {
    if !enabled() {
        return;
    }
    let arr = rows.iter()
                  .map(|r| Json::obj(vec![
                      ("component", Json::str(&r.component)),
                      ("dtype", Json::str(r.dtype.name())),
                      ("bytes", Json::num(r.bytes as f64)),
                  ]))
                  .collect();
    event("memory", vec![
        ("context", Json::str(context)),
        ("rows", Json::Arr(arr)),
        ("total", Json::num(mem_total(rows) as f64)),
    ]);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_measures_even_when_disabled() {
        // no enable(): the span must still return a real duration
        let sp = span("test", "disabled");
        std::thread::sleep(std::time::Duration::from_millis(2));
        let secs = sp.done();
        assert!(secs >= 0.001, "span under-measured: {secs}");
        assert!(!enabled());
    }

    #[test]
    fn train_rows_decompose_master_and_moments() {
        let rows = train_mem_rows(100, 30, 32, 64);
        assert_eq!(mem_total(&rows), 4 * 100 + 3 * 4 * 32 + 64);
        assert_eq!(rows[0].component, "master");
        assert_eq!(rows[0].bytes, 4 * 70);
        assert_eq!(rows[1].bytes, 4 * 30);
        let pool = rows.iter().find(|r| r.component == "candidate_pool");
        assert_eq!(pool.unwrap().dtype, DType::Bf16);
        // no pool → no row
        assert_eq!(train_mem_rows(100, 30, 32, 0).len(), 3);
    }

    #[test]
    fn tids_are_distinct_across_threads() {
        let a = tid();
        let b = std::thread::spawn(tid).join().unwrap();
        assert_ne!(a, b);
        assert_eq!(a, tid(), "tid must be stable per thread");
    }
}
