//! Serving subsystem: a continuous-batching model server with
//! multi-tenant LoRA adapters over ONE shared (quantized) frozen base.
//!
//! This is the deployment story the LoRA line of work promises —
//! "efficient task-switching during deployment" — made concrete: the
//! `serve` subcommand runs a long-lived HTTP/1.1 server (std `TcpListener`
//! only, no new dependencies) whose scheduler drives the existing
//! KV-cached `decode` loop continuously.  Requests prefill into a free
//! cache slot *mid-flight*, decode one token per step alongside whatever
//! else is in the batch, stream tokens back as NDJSON chunks, and retire
//! without stalling their peers; their slot is immediately reclaimable
//! by the next admission ([`crate::infer::kv_cache::KvCache::acquire`]).
//!
//! Multi-tenancy: N named adapters (`--adapter name=path`, repeatable)
//! are loaded once as detached [`crate::infer::AdapterSet`]s and served
//! over a single int8 `PackedStore` base — the request picks its
//! adapter, the forward path applies the low-rank delta per sequence
//! (`decode_adapted`), and the memory ledger shows exactly one
//! frozen-base copy no matter how many tenants ride it.
//!
//! Module map:
//! * [`http`] — request/response framing + chunked streaming writer.
//! * [`scheduler`] — bounded admission queue (backpressure → 429) and
//!   the continuous-batching decode loop, one thread, owns the cache.
//! * [`server`] — adapter registry, the accept/handler threads, routes,
//!   SIGTERM-triggered graceful drain.
//!
//! Log lines go through the leveled logger (stderr); stdout emits a
//! single machine-readable `{"serve_ready": ...}` line once the socket
//! is bound, which is how `tools/serve_smoke.py` discovers the port.

pub mod http;
pub mod scheduler;
pub mod server;

pub use scheduler::{Admission, FinishReason, Queue, SamplingSpec,
                    Scheduler, ServeRequest, ServeStats, TokenEvent};
pub use server::{AdapterRegistry, BaseSource, ServeConfig, Server};
