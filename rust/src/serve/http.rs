//! Thin HTTP/1.1 framing over std I/O — just enough protocol for the
//! serving endpoints: request-line + header parsing with a
//! `Content-Length` body, fixed responses, and a chunked
//! `Transfer-Encoding` writer for streaming token output.  Connections
//! are **persistent** (HTTP/1.1 keep-alive): each response advertises
//! `Connection: keep-alive` or `close` per request — honoring the
//! client's `Connection` header and the HTTP/1.0 default — and the
//! server loops reading requests off one socket until the client closes,
//! asks to, idles out, or hits the per-connection request bound.
//!
//! Every response goes out in as few `write` syscalls as possible: fixed
//! responses are one buffer (head + body), and each streamed chunk is
//! one buffer (size line + payload + CRLF).  With `TCP_NODELAY` set on
//! accepted sockets, a token chunk is exactly one small packet on the
//! wire instead of three Nagle-delayed fragments.
//!
//! Generic over `Read`/`Write` so the parsers unit-test against
//! in-memory buffers.

use std::io::{BufRead, Read, Write};

use anyhow::{bail, ensure, Context, Result};

/// Cap on the request line + headers, and on a request body.  Requests
/// here are small JSON documents; anything bigger is hostile or lost.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// name/value pairs in arrival order; names matched case-insensitively
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// protocol minor version: true for HTTP/1.1 (persistent by
    /// default), false for HTTP/1.0 (close by default)
    pub http11: bool,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// Whether the client wants the connection kept open after this
    /// request: an explicit `Connection: close` / `keep-alive` token
    /// wins, otherwise HTTP/1.1 defaults to persistent and HTTP/1.0 to
    /// close.
    pub fn wants_keep_alive(&self) -> bool {
        if let Some(v) = self.header("connection") {
            for tok in v.split(',') {
                let tok = tok.trim();
                if tok.eq_ignore_ascii_case("close") {
                    return false;
                }
                if tok.eq_ignore_ascii_case("keep-alive") {
                    return true;
                }
            }
        }
        self.http11
    }
}

/// Read one request.  `Ok(None)` means the peer closed the connection
/// cleanly before sending anything (a keep-alive probe, a port scan);
/// malformed framing is an error the caller answers with a 400.
pub fn read_request(r: &mut impl BufRead) -> Result<Option<Request>> {
    let mut line = String::new();
    if r.read_line(&mut line).context("reading request line")? == 0 {
        return Ok(None);
    }
    let mut head_bytes = line.len();
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .context("empty request line")?
        .to_string();
    let path = parts.next().context("request line without path")?
        .to_string();
    let version = parts.next().context("request line without version")?;
    ensure!(version.starts_with("HTTP/1."),
            "unsupported protocol version {version}");
    let http11 = version != "HTTP/1.0";
    let mut headers = Vec::new();
    loop {
        let mut hl = String::new();
        ensure!(r.read_line(&mut hl).context("reading header")? > 0,
                "connection closed mid-headers");
        head_bytes += hl.len();
        ensure!(head_bytes <= MAX_HEAD_BYTES, "request head too large");
        let hl = hl.trim_end_matches(['\r', '\n']);
        if hl.is_empty() {
            break;
        }
        let (k, v) = hl
            .split_once(':')
            .with_context(|| format!("malformed header line {hl:?}"))?;
        headers.push((k.trim().to_string(), v.trim().to_string()));
    }
    let len = match headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
    {
        Some((_, v)) => v
            .parse::<usize>()
            .with_context(|| format!("bad Content-Length {v:?}"))?,
        None => 0,
    };
    ensure!(len <= MAX_BODY_BYTES,
            "request body of {len} bytes exceeds {MAX_BODY_BYTES}");
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("reading request body")?;
    if method == "GET" || method == "POST" || method == "HEAD" {
        Ok(Some(Request { method, path, headers, body, http11 }))
    } else {
        bail!("unsupported method {method}")
    }
}

/// Canonical reason phrase for the statuses the server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// The `Connection` header value for a response.
fn conn_value(keep_alive: bool) -> &'static str {
    if keep_alive { "keep-alive" } else { "close" }
}

/// Write a complete fixed-length response (plus `extra` headers, e.g.
/// `Retry-After` on a 429) and flush.  Head and body are assembled into
/// one buffer — a single `write` syscall on a socket.
pub fn respond(w: &mut impl Write, status: u16, content_type: &str,
               body: &[u8], extra: &[(&str, &str)], keep_alive: bool)
    -> std::io::Result<()> {
    let mut buf = Vec::with_capacity(256 + body.len());
    let _ = write!(buf, "HTTP/1.1 {} {}\r\n", status, reason(status));
    let _ = write!(buf, "Content-Type: {content_type}\r\n");
    let _ = write!(buf, "Content-Length: {}\r\n", body.len());
    let _ = write!(buf, "Connection: {}\r\n", conn_value(keep_alive));
    for (k, v) in extra {
        let _ = write!(buf, "{k}: {v}\r\n");
    }
    buf.extend_from_slice(b"\r\n");
    buf.extend_from_slice(body);
    w.write_all(&buf)?;
    w.flush()
}

/// [`respond`] with a JSON body (newline-terminated).
pub fn respond_json(w: &mut impl Write, status: u16,
                    body: &crate::util::json::Json, keep_alive: bool)
    -> std::io::Result<()> {
    let mut s = body.to_string();
    s.push('\n');
    respond(w, status, "application/json", s.as_bytes(), &[], keep_alive)
}

/// Chunked `Transfer-Encoding` writer: each [`ChunkedWriter::chunk`] is
/// flushed immediately, so the peer sees tokens as they decode — the
/// "streamed tokens arrive incrementally" property the serve smoke test
/// asserts.  Size line, payload and trailing CRLF are coalesced into
/// ONE `write` syscall per chunk (three separate writes would interact
/// badly with Nagle on a streaming connection).  Call
/// [`ChunkedWriter::finish`] to write the terminal chunk.
pub struct ChunkedWriter<'a, W: Write> {
    w: &'a mut W,
    /// chunk assembly buffer, reused across tokens
    buf: Vec<u8>,
}

impl<'a, W: Write> ChunkedWriter<'a, W> {
    pub fn start(w: &'a mut W, status: u16, content_type: &str,
                 keep_alive: bool)
        -> std::io::Result<ChunkedWriter<'a, W>> {
        let mut buf = Vec::with_capacity(256);
        let _ = write!(buf, "HTTP/1.1 {} {}\r\n", status, reason(status));
        let _ = write!(buf, "Content-Type: {content_type}\r\n");
        let _ = write!(buf, "Transfer-Encoding: chunked\r\n");
        let _ = write!(buf, "Connection: {}\r\n\r\n",
                       conn_value(keep_alive));
        w.write_all(&buf)?;
        w.flush()?;
        buf.clear();
        Ok(ChunkedWriter { w, buf })
    }

    pub fn chunk(&mut self, data: &[u8]) -> std::io::Result<()> {
        if data.is_empty() {
            // a zero-length chunk is the stream terminator; skip
            return Ok(());
        }
        self.buf.clear();
        let _ = write!(self.buf, "{:x}\r\n", data.len());
        self.buf.extend_from_slice(data);
        self.buf.extend_from_slice(b"\r\n");
        self.w.write_all(&self.buf)?;
        self.w.flush()
    }

    pub fn finish(self) -> std::io::Result<()> {
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

/// Decode a chunked transfer-encoded body (the test client's half of
/// the protocol; the server only ever writes chunks).
pub fn decode_chunked(mut body: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    loop {
        let nl = body
            .windows(2)
            .position(|w| w == b"\r\n")
            .context("chunk size line without CRLF")?;
        let size_line = std::str::from_utf8(&body[..nl])
            .context("non-UTF8 chunk size")?;
        let size = usize::from_str_radix(
            size_line.split(';').next().unwrap_or("").trim(), 16)
            .with_context(|| format!("bad chunk size {size_line:?}"))?;
        body = &body[nl + 2..];
        if size == 0 {
            return Ok(out);
        }
        ensure!(body.len() >= size + 2, "truncated chunk payload");
        out.extend_from_slice(&body[..size]);
        ensure!(&body[size..size + 2] == b"\r\n",
                "chunk payload without trailing CRLF");
        body = &body[size + 2..];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_request_with_body() {
        let raw = b"POST /v1/generate HTTP/1.1\r\nHost: x\r\n\
                    Content-Length: 4\r\nContent-Type: application/json\
                    \r\n\r\n{\"\"}";
        let req = read_request(&mut Cursor::new(&raw[..]))
            .unwrap()
            .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.header("content-type"), Some("application/json"));
        assert_eq!(req.header("HOST"), Some("x"));
        assert_eq!(req.body, b"{\"\"}");
        assert!(req.http11 && req.wants_keep_alive());
    }

    #[test]
    fn connection_semantics_follow_header_and_version() {
        let parse = |raw: &str| {
            read_request(&mut Cursor::new(raw.as_bytes().to_vec()))
                .unwrap()
                .unwrap()
        };
        // HTTP/1.1 defaults to keep-alive; Connection: close overrides
        assert!(parse("GET / HTTP/1.1\r\n\r\n").wants_keep_alive());
        assert!(!parse("GET / HTTP/1.1\r\nConnection: close\r\n\r\n")
            .wants_keep_alive());
        assert!(!parse("GET / HTTP/1.1\r\nConnection: CLOSE\r\n\r\n")
            .wants_keep_alive());
        // HTTP/1.0 defaults to close; Connection: keep-alive overrides
        assert!(!parse("GET / HTTP/1.0\r\n\r\n").wants_keep_alive());
        assert!(
            parse("GET / HTTP/1.0\r\nConnection: Keep-Alive\r\n\r\n")
                .wants_keep_alive());
        // token lists are scanned, not string-matched
        assert!(!parse(
            "GET / HTTP/1.1\r\nConnection: foo, close\r\n\r\n")
            .wants_keep_alive());
    }

    #[test]
    fn clean_eof_is_none_and_garbage_is_err() {
        assert!(read_request(&mut Cursor::new(&b""[..]))
            .unwrap()
            .is_none());
        assert!(read_request(&mut Cursor::new(&b"nonsense\r\n\r\n"[..]))
            .is_err());
        let huge = format!("GET / HTTP/1.1\r\nX: {}\r\n\r\n",
                           "y".repeat(MAX_HEAD_BYTES));
        assert!(read_request(&mut Cursor::new(huge.as_bytes())).is_err());
        let bomb = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
                           MAX_BODY_BYTES + 1);
        assert!(read_request(&mut Cursor::new(bomb.as_bytes())).is_err());
    }

    #[test]
    fn fixed_response_roundtrip() {
        let mut out = Vec::new();
        respond(&mut out, 429, "application/json", b"{}",
                &[("Retry-After", "1")], false)
            .unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{s}");
        assert!(s.contains("Content-Length: 2\r\n"));
        assert!(s.contains("Connection: close\r\n"));
        assert!(s.contains("Retry-After: 1\r\n"));
        assert!(s.ends_with("\r\n\r\n{}"));
        // keep-alive responses advertise it so clients reuse the socket
        let mut out = Vec::new();
        respond(&mut out, 200, "application/json", b"{}", &[], true)
            .unwrap();
        let s = String::from_utf8(out).unwrap();
        assert!(s.contains("Connection: keep-alive\r\n"), "{s}");
    }

    #[test]
    fn chunked_stream_roundtrip() {
        let mut out = Vec::new();
        let mut cw = ChunkedWriter::start(&mut out, 200,
                                          "application/x-ndjson", true)
            .unwrap();
        cw.chunk(b"{\"token\":1}\n").unwrap();
        cw.chunk(b"").unwrap(); // no-op, must not terminate the stream
        cw.chunk(b"{\"done\":true}\n").unwrap();
        cw.finish().unwrap();
        let s = String::from_utf8(out.clone()).unwrap();
        let head_end = s.find("\r\n\r\n").unwrap() + 4;
        assert!(s.contains("Transfer-Encoding: chunked\r\n"));
        assert!(s.contains("Connection: keep-alive\r\n"));
        let body = decode_chunked(&out[head_end..]).unwrap();
        assert_eq!(body, b"{\"token\":1}\n{\"done\":true}\n");
    }
}
