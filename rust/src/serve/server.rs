//! The server proper: adapter registry, socket accept/handler threads,
//! HTTP routes, and SIGTERM-triggered graceful drain.
//!
//! Thread shape: the caller's thread becomes the scheduler (it owns the
//! runtime, the ONE shared base and the KV cache); one accept thread
//! hands each connection to a handler thread; handlers talk to the
//! scheduler only through the bounded [`Queue`] and a per-request mpsc
//! channel.  On SIGTERM/SIGINT (or `POST /admin/drain`) the accept
//! thread begins a drain: new requests get 503, everything admitted or
//! queued streams to completion, then the scheduler exits and
//! [`Server::run`] returns — clean shutdown with no truncated streams.
//!
//! Connections are persistent (HTTP/1.1 keep-alive): a handler thread
//! serves requests off one socket in a loop until the client sends
//! `Connection: close` (or speaks HTTP/1.0 without `keep-alive`), goes
//! idle past [`IDLE_TIMEOUT`], hits the [`MAX_REQUESTS_PER_CONN`]
//! bound, or the server starts draining — whichever comes first.  This
//! removes a TCP handshake (and two TIME_WAIT sockets) per request for
//! chatty clients; `tools/serve_smoke.py` and the serve bench exercise
//! the reuse path.
//!
//! Routes:
//! * `GET  /healthz` — liveness + queue/stream counters.
//! * `GET  /v1/adapters` — loaded adapters with resident byte costs.
//! * `POST /v1/generate` — body `{"prompt"|"tokens", "adapter"?,
//!   "max_new"?, "temperature"?, "top_k"?, "top_p"?, "seed"?, "stop"?,
//!   "stream"?}`; streams NDJSON token lines over chunked transfer
//!   encoding (default) or returns one JSON document (`"stream":false`).
//!   429 + `Retry-After` when the queue is full, 503 while draining.
//! * `POST /admin/drain` — trigger the graceful drain remotely (the
//!   portable stand-in for SIGTERM that the e2e tests use).

use std::collections::BTreeMap;
use std::io::{BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::checkpoint;
use crate::data::tokenizer::{ByteTokenizer, Tokenizer};
use crate::infer::adapters::{seeded_adapter, AdapterSet};
use crate::infer::sampler::Sampler;
use crate::model::layout::{Manifest, ParamStore, Variant};
use crate::model::packed::{PackedStore, ParamSource};
use crate::obs;
use crate::runtime::InferRuntime;
use crate::tensor::dtype::DType;
use crate::util::human_bytes;
use crate::util::json::Json;

use super::http::{self, ChunkedWriter, Request};
use super::scheduler::{Admission, Queue, SamplingSpec, Scheduler,
                       ServeRequest, ServeStats, TokenEvent};

/// Process-wide drain trigger.  Registered with the raw C `signal`
/// API so no new dependency is needed: the handler only stores a
/// relaxed atomic flag (async-signal-safe), and the accept loop polls
/// it between non-blocking accepts.
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static TRIGGERED: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_signal(_signum: i32) {
        TRIGGERED.store(true, Ordering::SeqCst);
    }

    extern "C" {
        // returns the previous handler as a pointer-sized integer
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> isize;
    }

    pub fn install() {
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGINT, on_signal);
            signal(SIGTERM, on_signal);
        }
    }

    pub fn triggered() -> bool {
        TRIGGERED.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod sig {
    pub fn install() {}

    pub fn triggered() -> bool {
        false
    }
}

/// The ONE shared frozen base every tenant decodes against: either the
/// master-precision store or a quantized [`PackedStore`] (the deployment
/// default).  `PackedStore` does not record its own base dtype, so the
/// packed form carries it for the memory ledger.
pub enum BaseSource {
    Master(ParamStore),
    Packed { store: PackedStore, dtype: DType },
}

impl BaseSource {
    pub fn as_source(&self) -> &dyn ParamSource {
        match self {
            BaseSource::Master(s) => s,
            BaseSource::Packed { store, .. } => store,
        }
    }

    pub fn describe(&self) -> String {
        match self {
            BaseSource::Master(s) => format!(
                "f32 master store ({})",
                human_bytes(4 * s.layout.total as u64)),
            BaseSource::Packed { store, dtype } => format!(
                "{dtype} packed store ({})",
                human_bytes(store.resident_bytes() as u64)),
        }
    }
}

/// Named adapters loaded at startup — the serve-time tenant table.
/// Insertion is startup-only; the serving threads share it read-only.
#[derive(Default)]
pub struct AdapterRegistry {
    by_name: BTreeMap<String, AdapterSet>,
}

impl AdapterRegistry {
    pub fn new() -> AdapterRegistry {
        AdapterRegistry::default()
    }

    pub fn insert(&mut self, ad: AdapterSet) -> Result<()> {
        ensure!(!self.by_name.contains_key(&ad.name),
                "duplicate adapter name {:?}", ad.name);
        self.by_name.insert(ad.name.clone(), ad);
        Ok(())
    }

    /// Load one `--adapter` spec: `name=path.ckpt` restores a
    /// LoRA-variant checkpoint and extracts its factors;
    /// `name=seed:N` seeds a fresh adapter (smoke tests and demos with
    /// no trained checkpoints on hand).
    pub fn load_spec(&mut self, manifest: &Manifest, spec: &str)
        -> Result<()> {
        let (name, src) = spec.split_once('=').with_context(|| {
            format!("--adapter {spec:?}: expected name=path or \
                     name=seed:N")
        })?;
        ensure!(!name.is_empty()
                    && name.chars().all(|c| {
                        c.is_ascii_alphanumeric() || "-_.".contains(c)
                    }),
                "--adapter name {name:?} must be non-empty \
                 [A-Za-z0-9._-]");
        ensure!(name != "base",
                "--adapter name \"base\" is reserved for the bare \
                 frozen base");
        let ad = match src.strip_prefix("seed:") {
            Some(seed) => {
                let seed: u64 = seed.parse().with_context(|| {
                    format!("--adapter {spec:?}: bad seed {seed:?}")
                })?;
                seeded_adapter(manifest, name, seed)?
            }
            None => {
                let layout = Arc::new(
                    manifest.layout(Variant::Lora)?.clone());
                let mut store = ParamStore::zeros(layout);
                let ck = checkpoint::load(&PathBuf::from(src))?;
                let rep = ck.restore_into(&mut store);
                ensure!(rep.loaded > 0,
                        "--adapter {spec:?}: checkpoint shares no \
                         parameters with the lora layout");
                crate::info!("adapter {name:?} from {src}: {} params \
                              loaded, {} absent, {} shape-mismatched",
                             rep.loaded, rep.missing, rep.mismatched);
                AdapterSet::from_store(manifest, &store, name)?
            }
        };
        self.insert(ad)
    }

    pub fn get(&self, name: &str) -> Option<&AdapterSet> {
        self.by_name.get(name)
    }

    pub fn names(&self) -> Vec<String> {
        self.by_name.keys().cloned().collect()
    }

    /// The scheduler's view: name → adapter.
    pub fn map(&self) -> &BTreeMap<String, AdapterSet> {
        &self.by_name
    }

    /// `(name, resident f32 bytes)` per adapter — the memory ledger's
    /// per-tenant rows.
    pub fn ledger(&self) -> Vec<(String, u64)> {
        self.by_name
            .iter()
            .map(|(n, a)| (n.clone(), a.resident_bytes() as u64))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }
}

/// `serve` subcommand knobs.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub host: String,
    pub port: u16,
    /// concurrent sequences in the decode batch (KV-cache slots)
    pub max_batch: usize,
    /// admission-queue bound; beyond it, requests get 429
    pub queue_depth: usize,
    /// per-sequence KV capacity (prompt + generated)
    pub max_context: usize,
    /// `max_new` when the request body leaves it unset
    pub default_max_new: usize,
    /// prompt tokens prefilled per scheduler iteration (0 = whole
    /// prompt at once); bounds how long a long prompt can stall the
    /// decode batch between token emissions
    pub prefill_chunk: usize,
    /// positions per paged-KV block; pool bytes grow in units of
    /// `block × heads × head_dim` per layer side
    pub kv_block: usize,
    /// share sealed KV blocks across requests with a common prompt
    /// prefix (`--prefix-cache on|off`); off is a strict no-op
    pub prefix_cache: bool,
    /// LRU budget of released-but-retained blocks kept warm for future
    /// admissions (`--prefix-cache-blocks`)
    pub prefix_cache_blocks: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            host: "127.0.0.1".to_string(),
            port: 8080,
            max_batch: 4,
            queue_depth: 16,
            max_context: 256,
            default_max_new: 64,
            prefill_chunk: 32,
            kv_block: crate::infer::kv_cache::DEFAULT_KV_BLOCK,
            prefix_cache: true,
            prefix_cache_blocks: 128,
        }
    }
}

/// State the accept/handler threads share with the scheduler thread.
struct Shared {
    queue: Queue,
    stats: ServeStats,
    /// set by `POST /admin/drain`; the accept loop turns it into a drain
    shutdown: AtomicBool,
    vocab: usize,
    max_context: usize,
    default_max_new: usize,
    /// whether the KV prefix cache is on (the `/healthz` report)
    prefix_cache: bool,
    adapter_names: Vec<String>,
    adapter_ledger: Vec<(String, u64)>,
    next_id: AtomicU64,
}

/// A bound, not-yet-running server.  [`Server::bind`] then
/// [`Server::run`] — split so tests (and `--port 0` callers) can read
/// [`Server::local_addr`] before the accept loop starts.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
    rt: Box<dyn InferRuntime>,
    base: BaseSource,
    registry: AdapterRegistry,
    cfg: ServeConfig,
}

impl Server {
    pub fn bind(cfg: ServeConfig, rt: Box<dyn InferRuntime>,
                base: BaseSource, registry: AdapterRegistry,
                vocab: usize) -> Result<Server> {
        ensure!(cfg.max_batch >= 1, "--max-batch must be >= 1");
        ensure!(cfg.queue_depth >= 1, "--queue-depth must be >= 1");
        ensure!(cfg.max_context >= 2,
                "--max-context must fit a prompt token and a generated \
                 token");
        ensure!(cfg.default_max_new >= 1, "--max-new must be >= 1");
        ensure!(cfg.kv_block >= 1, "--kv-block must be >= 1");
        let listener =
            TcpListener::bind(format!("{}:{}", cfg.host, cfg.port))
                .with_context(|| {
                    format!("binding {}:{}", cfg.host, cfg.port)
                })?;
        // non-blocking accept so the loop can poll the drain flag
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            queue: Queue::new(cfg.queue_depth),
            stats: ServeStats::default(),
            shutdown: AtomicBool::new(false),
            vocab,
            max_context: cfg.max_context,
            default_max_new: cfg.default_max_new,
            prefix_cache: cfg.prefix_cache,
            adapter_names: registry.names(),
            adapter_ledger: registry.ledger(),
            next_id: AtomicU64::new(1),
        });
        Ok(Server { listener, shared, rt, base, registry, cfg })
    }

    /// The bound address (resolves `--port 0` to the kernel's pick).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until drained: SIGTERM/SIGINT or `POST /admin/drain` stops
    /// admissions, in-flight work completes, then this returns.
    pub fn run(self) -> Result<()> {
        sig::install();
        let Server { listener, shared, rt, base, registry, cfg } = self;
        let addr = listener.local_addr()?;
        crate::info!(
            "serving on http://{addr} — base: {}; {} adapter(s): [{}]; \
             max-batch {}, queue-depth {}, max-context {}, \
             prefill-chunk {}, kv-block {}, prefix-cache {}",
            base.describe(), registry.len(),
            shared.adapter_names.join(", "), cfg.max_batch,
            cfg.queue_depth, cfg.max_context, cfg.prefill_chunk,
            cfg.kv_block,
            if cfg.prefix_cache {
                format!("on({} blocks)", cfg.prefix_cache_blocks)
            } else {
                "off".to_string()
            });
        // the ONE machine-readable stdout line: how tools/serve_smoke.py
        // discovers a --port 0 server's actual port
        let ready = Json::obj(vec![(
            "serve_ready",
            Json::obj(vec![
                ("host", Json::str(&addr.ip().to_string())),
                ("port", Json::num(addr.port() as f64)),
            ]),
        )])
        .to_string();
        println!("{ready}");
        let _ = std::io::stdout().flush();
        let accept_shared = Arc::clone(&shared);
        let accept = thread::spawn(move || {
            let mut handlers: Vec<thread::JoinHandle<()>> = Vec::new();
            loop {
                if sig::triggered()
                    || accept_shared.shutdown.load(Ordering::SeqCst)
                {
                    accept_shared.queue.begin_drain();
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let s = Arc::clone(&accept_shared);
                        handlers.push(thread::spawn(move || {
                            handle(stream, &s)
                        }));
                        if handlers.len() >= 64 {
                            handlers.retain(|h| !h.is_finished());
                        }
                    }
                    Err(e)
                        if e.kind()
                            == std::io::ErrorKind::WouldBlock =>
                    {
                        thread::sleep(Duration::from_millis(10));
                    }
                    Err(e) => {
                        crate::warnlog!("accept: {e}");
                        thread::sleep(Duration::from_millis(10));
                    }
                }
            }
            handlers
        });
        let mut cache = rt.new_cache_blocked(cfg.max_batch,
                                             cfg.max_context,
                                             cfg.kv_block);
        if cfg.prefix_cache {
            cache.enable_prefix(cfg.prefix_cache_blocks);
            crate::info!(
                "prefix cache: on — sealed {}-position blocks shared \
                 across same-tenant prompts, LRU pool of {} blocks \
                 ({} budget)",
                cache.block, cfg.prefix_cache_blocks,
                human_bytes((cfg.prefix_cache_blocks
                             * cache.block_bytes()) as u64));
        }
        crate::info!(
            "paged KV pool: up to {} blocks of {} positions ({} each, \
             {} ceiling); nothing pre-reserved",
            cache.max_blocks(), cache.block,
            human_bytes(cache.block_bytes() as u64),
            human_bytes(
                (cache.max_blocks() * cache.block_bytes()) as u64));
        if let BaseSource::Packed { store, dtype } = &base {
            // the zero-base-duplication ledger: one frozen-base copy no
            // matter how many tenants; totals equal resident_bytes()
            // exactly (test-pinned in rust/tests/serving.rs)
            let rows = obs::serve_mem_rows(store, *dtype,
                                           &shared.adapter_ledger,
                                           &cache);
            obs::memory_event("serve", &rows);
            for r in &rows {
                crate::info!("  mem {:<20} {:>5} {:>10}", r.component,
                             r.dtype.name(), human_bytes(r.bytes));
            }
            crate::info!("  mem {:<20} {:>5} {:>10}", "total", "",
                         human_bytes(obs::mem_total(&rows)));
        }
        Scheduler::new(rt.as_ref(), base.as_source(), registry.map(),
                       cache)
            .with_prefill_chunk(cfg.prefill_chunk)
            .run(&shared.queue, &shared.stats);
        // scheduler exited: drain is complete; reap the I/O threads
        let handlers = accept
            .join()
            .unwrap_or_default();
        for h in handlers {
            let _ = h.join();
        }
        let s = &shared.stats;
        let per: Vec<String> = s
            .adapter_counts()
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect();
        crate::info!(
            "drained: {} received, {} completed, {} rejected, {} \
             cancelled, {} tokens streamed, {} prefilled, {} prefix-hit{}",
            s.received.load(Ordering::Relaxed),
            s.completed.load(Ordering::Relaxed),
            s.rejected.load(Ordering::Relaxed),
            s.cancelled.load(Ordering::Relaxed),
            s.tokens_streamed.load(Ordering::Relaxed),
            s.prefilled_tokens.load(Ordering::Relaxed),
            s.prefix_hit_tokens.load(Ordering::Relaxed),
            if per.is_empty() {
                String::new()
            } else {
                format!("; requests by tenant: {}", per.join("  "))
            });
        Ok(())
    }
}

/// How long a kept-alive connection may sit idle between requests
/// before the handler closes it.  Doubles as the per-read timeout while
/// parsing a request, so a stalled client cannot pin a thread.
const IDLE_TIMEOUT: Duration = Duration::from_secs(5);

/// Requests served per connection before the handler closes it anyway —
/// bounds how long one socket can monopolise a handler thread.
const MAX_REQUESTS_PER_CONN: usize = 128;

/// `true` when an error chain bottoms out in a read timeout — a
/// kept-alive client that simply stopped talking, not a protocol error.
fn is_idle_timeout(e: &anyhow::Error) -> bool {
    e.chain().any(|c| {
        c.downcast_ref::<std::io::Error>().is_some_and(|io| {
            matches!(io.kind(),
                     std::io::ErrorKind::TimedOut
                     | std::io::ErrorKind::WouldBlock)
        })
    })
}

/// One connection, many requests (HTTP/1.1 keep-alive).
fn handle(stream: TcpStream, shared: &Arc<Shared>) {
    if let Err(e) = try_handle(stream, shared) {
        crate::debuglog!("handler: {e:#}");
    }
}

fn try_handle(stream: TcpStream, shared: &Arc<Shared>) -> Result<()> {
    // the listener is non-blocking; its accepted sockets must not be
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(IDLE_TIMEOUT))?;
    stream.set_write_timeout(Some(Duration::from_secs(30)))?;
    // token lines are tiny; never let Nagle hold one back
    let _ = stream.set_nodelay(true);
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut w = stream;
    for served in 0.. {
        let req = match http::read_request(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => return Ok(()), // clean close between requests
            Err(e) if is_idle_timeout(&e) => return Ok(()),
            Err(e) => {
                let body = Json::obj(vec![(
                    "error", Json::str(&format!("{e:#}")))]);
                http::respond_json(&mut w, 400, &body, false)?;
                return Ok(());
            }
        };
        // draining forces close so handler threads exit with the
        // scheduler instead of idling out one by one
        let keep = req.wants_keep_alive()
            && served + 1 < MAX_REQUESTS_PER_CONN
            && !shared.queue.is_draining();
        let open = route(&mut w, &req, shared, keep)?;
        if !(keep && open) {
            return Ok(());
        }
    }
    Ok(())
}

/// Dispatch one request.  `keep` is what the response headers promise;
/// the return value is whether the connection is actually still usable
/// (`false` when a streaming client hung up mid-response).
fn route(w: &mut TcpStream, req: &Request, shared: &Arc<Shared>,
         keep: bool) -> Result<bool> {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            healthz(w, shared, keep)?;
            Ok(keep)
        }
        ("GET", "/v1/adapters") => {
            adapters_route(w, shared, keep)?;
            Ok(keep)
        }
        ("POST", "/admin/drain") => {
            shared.shutdown.store(true, Ordering::SeqCst);
            let body = Json::obj(vec![("draining", Json::Bool(true))]);
            // the drain request itself never keeps the socket open
            http::respond_json(w, 200, &body, false)?;
            Ok(false)
        }
        ("POST", "/v1/generate") => generate_route(w, req, shared, keep),
        _ => {
            let body = Json::obj(vec![(
                "error",
                Json::str(&format!("no route {} {}", req.method,
                                   req.path)))]);
            http::respond_json(w, 404, &body, keep)?;
            Ok(keep)
        }
    }
}

fn healthz(w: &mut TcpStream, shared: &Arc<Shared>, keep: bool)
    -> Result<()> {
    let s = &shared.stats;
    let by_tenant: BTreeMap<String, Json> = shared
        .queue
        .depths()
        .into_iter()
        .map(|(n, d)| (n, Json::num(d as f64)))
        .collect();
    let body = Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("draining", Json::Bool(shared.queue.is_draining())),
        ("active", Json::num(s.active.load(Ordering::Relaxed) as f64)),
        ("queued", Json::num(shared.queue.len() as f64)),
        ("queued_by_tenant", Json::Obj(by_tenant)),
        ("received",
         Json::num(s.received.load(Ordering::Relaxed) as f64)),
        ("completed",
         Json::num(s.completed.load(Ordering::Relaxed) as f64)),
        ("rejected",
         Json::num(s.rejected.load(Ordering::Relaxed) as f64)),
        ("tokens_streamed",
         Json::num(s.tokens_streamed.load(Ordering::Relaxed) as f64)),
        ("prefilled_tokens",
         Json::num(s.prefilled_tokens.load(Ordering::Relaxed) as f64)),
        ("prefix_cache",
         Json::obj(vec![
             ("enabled", Json::Bool(shared.prefix_cache)),
             ("hit_blocks",
              Json::num(s.prefix_hit_blocks.load(Ordering::Relaxed)
                        as f64)),
             ("miss_blocks",
              Json::num(s.prefix_miss_blocks.load(Ordering::Relaxed)
                        as f64)),
             ("hit_tokens",
              Json::num(s.prefix_hit_tokens.load(Ordering::Relaxed)
                        as f64)),
             ("evicted",
              Json::num(s.prefix_evicted.load(Ordering::Relaxed)
                        as f64)),
             ("pool_blocks",
              Json::num(s.prefix_pool_blocks.load(Ordering::Relaxed)
                        as f64)),
             ("shared_blocks",
              Json::num(s.prefix_shared_blocks.load(Ordering::Relaxed)
                        as f64)),
         ])),
        ("adapters",
         Json::Arr(shared
             .adapter_names
             .iter()
             .map(|n| Json::str(n))
             .collect())),
    ]);
    http::respond_json(w, 200, &body, keep)?;
    Ok(())
}

fn adapters_route(w: &mut TcpStream, shared: &Arc<Shared>, keep: bool)
    -> Result<()> {
    let arr = shared
        .adapter_ledger
        .iter()
        .map(|(n, b)| Json::obj(vec![
            ("name", Json::str(n)),
            ("resident_bytes", Json::num(*b as f64)),
        ]))
        .collect();
    http::respond_json(w, 200, &Json::Arr(arr), keep)?;
    Ok(())
}

/// A parsed + validated `/v1/generate` body.
struct GenRequest {
    adapter: Option<String>,
    prompt: Vec<i32>,
    spec: SamplingSpec,
    stream: bool,
}

fn parse_generate(body: &[u8], shared: &Shared) -> Result<GenRequest> {
    let text = std::str::from_utf8(body).context("body is not UTF-8")?;
    let j = Json::parse(if text.trim().is_empty() { "{}" } else { text })
        .context("body is not JSON")?;
    let prompt: Vec<i32> = if let Some(t) = j.opt("tokens") {
        t.as_arr()
            .context("\"tokens\"")?
            .iter()
            .map(|x| {
                let v = x.as_usize().context("\"tokens\" entry")?;
                ensure!(v < shared.vocab,
                        "token {v} outside vocab {}", shared.vocab);
                Ok(v as i32)
            })
            .collect::<Result<_>>()?
    } else if let Some(p) = j.opt("prompt") {
        ByteTokenizer::new(shared.vocab)
            .encode(p.as_str().context("\"prompt\"")?)
    } else {
        bail!("body needs \"prompt\" (string) or \"tokens\" (int array)")
    };
    ensure!(!prompt.is_empty(), "prompt encodes to zero tokens");
    ensure!(prompt.len() <= shared.max_context,
            "prompt of {} tokens exceeds --max-context {}", prompt.len(),
            shared.max_context);
    let adapter = match j.opt("adapter") {
        Some(Json::Null) | None => None,
        Some(a) => {
            let name = a.as_str().context("\"adapter\"")?;
            ensure!(shared.adapter_names.iter().any(|n| n == name),
                    "unknown adapter {name:?} (loaded: {})",
                    shared.adapter_names.join(", "));
            Some(name.to_string())
        }
    };
    let max_new = match j.opt("max_new") {
        Some(v) => v.as_usize().context("\"max_new\"")?,
        None => shared.default_max_new,
    };
    ensure!(max_new >= 1, "max_new must be >= 1");
    let temperature = match j.opt("temperature") {
        Some(v) => v.as_f64().context("\"temperature\"")? as f32,
        None => 0.0,
    };
    ensure!(temperature.is_finite() && temperature >= 0.0,
            "temperature must be finite and >= 0");
    let top_k = match j.opt("top_k") {
        Some(v) => v.as_usize().context("\"top_k\"")?,
        None => 0,
    };
    let top_p = match j.opt("top_p") {
        Some(v) => v.as_f64().context("\"top_p\"")? as f32,
        None => 1.0,
    };
    ensure!(top_p > 0.0 && top_p <= 1.0,
            "top_p must be in (0, 1] (1 disables nucleus filtering)");
    let seed = match j.opt("seed") {
        Some(v) => v.as_f64().context("\"seed\"")? as u64,
        None => 42,
    };
    let stop_tokens: Vec<i32> = match j.opt("stop") {
        Some(v) => v
            .as_arr()
            .context("\"stop\"")?
            .iter()
            .map(|x| Ok(x.as_usize().context("\"stop\" entry")? as i32))
            .collect::<Result<_>>()?,
        None => Vec::new(),
    };
    let stream = match j.opt("stream") {
        Some(v) => v.as_bool().context("\"stream\"")?,
        None => true,
    };
    Ok(GenRequest {
        adapter,
        prompt,
        spec: SamplingSpec {
            sampler: Sampler { temperature, top_k, top_p },
            seed,
            max_new,
            stop_tokens,
        },
        stream,
    })
}

/// Ceiling on waiting for the scheduler to produce the next event —
/// far beyond any real decode step; hitting it means the scheduler
/// thread is gone.
const EVENT_TIMEOUT: Duration = Duration::from_secs(300);

fn generate_route(w: &mut TcpStream, req: &Request,
                  shared: &Arc<Shared>, keep: bool) -> Result<bool> {
    let gr = match parse_generate(&req.body, shared) {
        Ok(g) => g,
        Err(e) => {
            let body = Json::obj(vec![(
                "error", Json::str(&format!("{e:#}")))]);
            http::respond_json(w, 400, &body, keep)?;
            return Ok(keep);
        }
    };
    shared.stats.received.fetch_add(1, Ordering::Relaxed);
    let (tx, rx) = channel();
    let sreq = ServeRequest {
        id: shared.next_id.fetch_add(1, Ordering::Relaxed),
        adapter: gr.adapter,
        prompt: gr.prompt,
        spec: gr.spec,
        tx,
        enqueued: Instant::now(),
    };
    match shared.queue.push(sreq) {
        Admission::Full => {
            shared.stats.rejected.fetch_add(1, Ordering::Relaxed);
            obs::add("serve.http_429", 1);
            let mut body = Json::obj(vec![(
                "error",
                Json::str("admission queue full, retry later"))])
                .to_string();
            body.push('\n');
            http::respond(w, 429, "application/json", body.as_bytes(),
                          &[("Retry-After", "1")], keep)?;
            return Ok(keep);
        }
        Admission::Draining => {
            shared.stats.rejected.fetch_add(1, Ordering::Relaxed);
            let body = Json::obj(vec![(
                "error", Json::str("server is draining"))]);
            http::respond_json(w, 503, &body, false)?;
            return Ok(false);
        }
        Admission::Queued => {}
    }
    let tok = ByteTokenizer::new(shared.vocab);
    let mut toks: Vec<i32> = Vec::new();
    if gr.stream {
        // NDJSON over chunked transfer encoding: one line per token,
        // flushed as it decodes, then a final summary line
        let mut cw =
            ChunkedWriter::start(w, 200, "application/x-ndjson", keep)?;
        loop {
            match rx.recv_timeout(EVENT_TIMEOUT) {
                Ok(TokenEvent::Token(t)) => {
                    toks.push(t);
                    let mut line = Json::obj(vec![
                        ("token", Json::num(t as f64)),
                        ("index", Json::num((toks.len() - 1) as f64)),
                    ])
                    .to_string();
                    line.push('\n');
                    if cw.chunk(line.as_bytes()).is_err() {
                        // client went away; the scheduler notices on
                        // its next send and reclaims the slot
                        return Ok(false);
                    }
                }
                Ok(TokenEvent::Done { finish, n_generated }) => {
                    let mut line = Json::obj(vec![
                        ("done", Json::Bool(true)),
                        ("finish", Json::str(finish.as_str())),
                        ("n_generated",
                         Json::num(n_generated as f64)),
                        ("text", Json::str(&tok.decode(&toks))),
                    ])
                    .to_string();
                    line.push('\n');
                    let sent = cw.chunk(line.as_bytes()).is_ok()
                        && cw.finish().is_ok();
                    return Ok(keep && sent);
                }
                Ok(TokenEvent::Error(e)) => {
                    let mut line = Json::obj(vec![(
                        "error", Json::str(&e))])
                        .to_string();
                    line.push('\n');
                    let sent = cw.chunk(line.as_bytes()).is_ok()
                        && cw.finish().is_ok();
                    return Ok(keep && sent);
                }
                Err(RecvTimeoutError::Timeout)
                | Err(RecvTimeoutError::Disconnected) => {
                    let _ = cw.chunk(
                        b"{\"error\":\"generation stream closed\"}\n");
                    let _ = cw.finish();
                    return Ok(false);
                }
            }
        }
    }
    // non-streaming: collect everything, answer with one document
    loop {
        match rx.recv_timeout(EVENT_TIMEOUT) {
            Ok(TokenEvent::Token(t)) => toks.push(t),
            Ok(TokenEvent::Done { finish, n_generated }) => {
                let body = Json::obj(vec![
                    ("tokens",
                     Json::Arr(toks
                         .iter()
                         .map(|&t| Json::num(t as f64))
                         .collect())),
                    ("text", Json::str(&tok.decode(&toks))),
                    ("finish", Json::str(finish.as_str())),
                    ("n_generated", Json::num(n_generated as f64)),
                ]);
                http::respond_json(w, 200, &body, keep)?;
                return Ok(keep);
            }
            Ok(TokenEvent::Error(e)) => {
                let body =
                    Json::obj(vec![("error", Json::str(&e))]);
                http::respond_json(w, 500, &body, keep)?;
                return Ok(keep);
            }
            Err(RecvTimeoutError::Timeout)
            | Err(RecvTimeoutError::Disconnected) => {
                let body = Json::obj(vec![(
                    "error",
                    Json::str("generation stream closed"))]);
                http::respond_json(w, 500, &body, false)?;
                return Ok(false);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_shared() -> Shared {
        Shared {
            queue: Queue::new(4),
            stats: ServeStats::default(),
            shutdown: AtomicBool::new(false),
            vocab: 256,
            max_context: 32,
            default_max_new: 8,
            prefix_cache: true,
            adapter_names: vec!["a".to_string(), "b".to_string()],
            adapter_ledger: vec![("a".to_string(), 100),
                                 ("b".to_string(), 100)],
            next_id: AtomicU64::new(1),
        }
    }

    #[test]
    fn registry_loads_seeded_specs_and_rejects_duplicates() {
        let man = Manifest::builtin("tiny").unwrap();
        let mut reg = AdapterRegistry::new();
        reg.load_spec(&man, "t1=seed:7").unwrap();
        reg.load_spec(&man, "t2=seed:9").unwrap();
        assert_eq!(reg.names(), vec!["t1", "t2"]);
        assert!(reg.load_spec(&man, "t1=seed:11").is_err());
        assert!(reg.load_spec(&man, "no-equals-sign").is_err());
        assert!(reg.load_spec(&man, "base=seed:1").is_err());
        assert!(reg.load_spec(&man, "bad name=seed:1").is_err());
        assert!(reg.load_spec(&man, "t3=seed:notanumber").is_err());
        let ledger = reg.ledger();
        assert_eq!(ledger.len(), 2);
        assert_eq!(ledger[0].1,
                   reg.get("t1").unwrap().resident_bytes() as u64);
    }

    #[test]
    fn generate_body_defaults_and_validation() {
        let sh = test_shared();
        let g = parse_generate(br#"{"prompt":"hi","adapter":"a"}"#, &sh)
            .unwrap();
        assert_eq!(g.prompt, vec![104, 105]);
        assert_eq!(g.adapter.as_deref(), Some("a"));
        assert_eq!(g.spec.max_new, 8);
        assert_eq!(g.spec.seed, 42);
        assert_eq!(g.spec.sampler.top_k, 0);
        assert_eq!(g.spec.sampler.top_p, 1.0);
        assert!(g.stream);

        let g = parse_generate(
            br#"{"tokens":[1,2,3],"max_new":2,"temperature":0.5,
                 "top_k":5,"top_p":0.9,"seed":7,"stop":[0],
                 "stream":false}"#,
            &sh)
            .unwrap();
        assert_eq!(g.prompt, vec![1, 2, 3]);
        assert!(g.adapter.is_none());
        assert_eq!(g.spec.max_new, 2);
        assert_eq!(g.spec.sampler.top_k, 5);
        assert_eq!(g.spec.sampler.top_p, 0.9);
        assert_eq!(g.spec.stop_tokens, vec![0]);
        assert!(!g.stream);

        assert!(parse_generate(b"{}", &sh).is_err()); // no prompt
        assert!(parse_generate(b"not json", &sh).is_err());
        assert!(parse_generate(br#"{"prompt":""}"#, &sh).is_err());
        assert!(parse_generate(br#"{"prompt":"x","adapter":"nope"}"#,
                               &sh)
            .is_err());
        assert!(parse_generate(br#"{"tokens":[999]}"#, &sh).is_err());
        assert!(parse_generate(br#"{"prompt":"x","max_new":0}"#, &sh)
            .is_err());
        assert!(parse_generate(br#"{"prompt":"x","top_p":0}"#, &sh)
            .is_err());
        // a prompt longer than --max-context is refused up front
        let long = format!(r#"{{"prompt":"{}"}}"#, "y".repeat(33));
        assert!(parse_generate(long.as_bytes(), &sh).is_err());
    }

    #[test]
    fn serve_config_defaults_are_sane() {
        let c = ServeConfig::default();
        assert_eq!(c.port, 8080);
        assert!(c.max_batch >= 1 && c.queue_depth >= c.max_batch);
        assert_eq!(c.kv_block,
                   crate::infer::kv_cache::DEFAULT_KV_BLOCK);
        assert!(c.prefill_chunk > 0,
                "serve should default to chunked prefill");
        assert!(c.prefix_cache, "prefix sharing should default on");
        assert!(c.prefix_cache_blocks > 0);
    }
}
