//! Continuous-batching scheduler: the server's decode engine.
//!
//! One scheduler thread owns the model runtime, the shared base
//! parameters, the adapter registry and the KV cache; handler threads
//! only touch the bounded admission [`Queue`].  Each loop iteration
//! admits queued requests into free cache slots, advances at most one
//! pending prefill by one `--prefill-chunk` slice, then advances every
//! active sequence by one token with a single batched `decode_adapted`
//! call — so a request joins the batch mid-flight, streams tokens over
//! its channel as they decode, and leaves on stop/length without
//! stalling its peers, whose cache slot the next admission reclaims.
//!
//! **Chunked prefill:** a long prompt no longer monopolizes the loop.
//! Its prefill runs `--prefill-chunk` tokens at a time, one chunk per
//! iteration, interleaved with the batch's decode steps — so an
//! in-flight peer's time-between-tokens is bounded by one chunk of
//! forward work, not by the whole joining prompt.  Chunking is
//! *token-identical* to monolithic prefill: each cached position's K/V
//! and the final position's logits depend only on its own row and the
//! rows before it, so splitting the prompt changes addresses, never
//! values (`rust/tests/serving.rs` pins the streams equal).
//!
//! **Prefix cache** (`--prefix-cache`): admission asks the KV cache for
//! the longest sealed-block prefix of the incoming prompt
//! ([`KvCache::admit_prefix`]) — a hit splices the shared blocks into
//! the new slot and prefill starts at the first uncached position, so
//! a warm request's TTFT covers only its unique suffix.  After every
//! prefill chunk and decode step the scheduler records the cached
//! token ids ([`KvCache::note_tokens`]) so full blocks seal and become
//! shareable.  Sharing is invisible to the math: sealed blocks hold
//! exactly the rows a cold prefill would recompute, so warm and cold
//! decodes are bitwise identical (`rust/tests/serving.rs`).
//!
//! Determinism: a request's sampling stream is `Rng::new(seed).fork(0)`
//! — the same stream a solo `generate` run at sequence index 0 uses —
//! and the kernels compute each batch row independently, so the tokens
//! a request receives do not depend on what else shares its batch
//! (`rust/tests/serving.rs` pins this bitwise).
//!
//! Backpressure: [`Queue::push`] rejects when `--queue-depth` requests
//! are already waiting across all tenants (the handler answers 429) or
//! once a drain has begun (503).  Admission is **fair per tenant**: the
//! queue keeps one FIFO lane per adapter name and hands requests out
//! round-robin across non-empty lanes, so one chatty tenant can fill
//! its own lane but cannot starve a quieter one out of decode slots.
//! Graceful drain: everything already admitted or queued runs to
//! completion; only new arrivals are refused.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::infer::adapters::AdapterSet;
use crate::infer::kv_cache::KvCache;
use crate::infer::sampler::Sampler;
use crate::model::packed::ParamSource;
use crate::obs;
use crate::runtime::InferRuntime;
use crate::util::rng::Rng;

/// Per-request sampling parameters (the HTTP body's knobs).
#[derive(Clone, Debug)]
pub struct SamplingSpec {
    pub sampler: Sampler,
    pub seed: u64,
    /// tokens to generate (counting a terminating stop token)
    pub max_new: usize,
    pub stop_tokens: Vec<i32>,
}

/// Why a request's stream ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// emitted a stop token
    Stop,
    /// generated `max_new` tokens
    Length,
    /// its KV-cache slot reached `--max-context`
    ContextFull,
}

impl FinishReason {
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Stop => "stop",
            FinishReason::Length => "length",
            FinishReason::ContextFull => "context_full",
        }
    }
}

/// One unit of streamed progress, sent over the request's channel to
/// the handler thread that owns the client socket.
#[derive(Clone, Debug)]
pub enum TokenEvent {
    Token(i32),
    Done { finish: FinishReason, n_generated: usize },
    Error(String),
}

/// A validated request handed from an HTTP handler to the scheduler.
pub struct ServeRequest {
    pub id: u64,
    /// registry name; `None` serves the bare base
    pub adapter: Option<String>,
    pub prompt: Vec<i32>,
    pub spec: SamplingSpec,
    pub tx: Sender<TokenEvent>,
    pub enqueued: Instant,
}

/// Admission verdict from [`Queue::push`].
#[derive(Debug, PartialEq, Eq)]
pub enum Admission {
    Queued,
    /// queue at `--queue-depth`: answer 429 (backpressure)
    Full,
    /// drain in progress: answer 503
    Draining,
}

struct QueueInner {
    /// one FIFO lane per tenant (adapter name, or "base" for bare-base
    /// requests), in first-arrival order; lanes persist once created so
    /// the round-robin cursor stays meaningful
    lanes: Vec<(String, VecDeque<ServeRequest>)>,
    /// next lane the round-robin scan starts from
    cursor: usize,
    /// requests waiting across all lanes (the `--queue-depth` bound)
    total: usize,
    draining: bool,
}

impl QueueInner {
    /// Pop round-robin: the first non-empty lane at or after `cursor`,
    /// then advance the cursor past it so the next pop favors the next
    /// tenant.  Single-tenant traffic degenerates to plain FIFO.
    fn pop_rr(&mut self) -> Option<ServeRequest> {
        if self.total == 0 || self.lanes.is_empty() {
            return None;
        }
        let n = self.lanes.len();
        for i in 0..n {
            let at = (self.cursor + i) % n;
            if let Some(req) = self.lanes[at].1.pop_front() {
                self.cursor = (at + 1) % n;
                self.total -= 1;
                return Some(req);
            }
        }
        None
    }
}

/// Bounded MPSC admission queue between handler threads and the
/// scheduler thread: one FIFO lane per tenant, handed out round-robin.
pub struct Queue {
    inner: Mutex<QueueInner>,
    cv: Condvar,
    depth: usize,
}

impl Queue {
    pub fn new(depth: usize) -> Queue {
        assert!(depth > 0, "queue depth must be positive");
        Queue {
            inner: Mutex::new(QueueInner {
                lanes: Vec::new(),
                cursor: 0,
                total: 0,
                draining: false,
            }),
            cv: Condvar::new(),
            depth,
        }
    }

    /// Try to enqueue; on `Full`/`Draining` the request is dropped here
    /// (the handler still owns the receiving end and answers the client
    /// itself).  The depth bound is global across tenants — fairness
    /// shapes *dequeue* order, not queue capacity.
    pub fn push(&self, req: ServeRequest) -> Admission {
        let mut g = self.inner.lock().unwrap();
        if g.draining {
            return Admission::Draining;
        }
        if g.total >= self.depth {
            return Admission::Full;
        }
        let tenant = req.adapter.as_deref().unwrap_or("base");
        match g.lanes.iter_mut().find(|(n, _)| n == tenant) {
            Some((_, lane)) => lane.push_back(req),
            None => {
                let name = tenant.to_string();
                g.lanes.push((name, VecDeque::from([req])));
            }
        }
        g.total += 1;
        self.cv.notify_one();
        Admission::Queued
    }

    pub fn try_pop(&self) -> Option<ServeRequest> {
        self.inner.lock().unwrap().pop_rr()
    }

    /// Block up to `timeout` for a request (the scheduler's idle wait).
    /// Returns immediately once draining with an empty queue.
    pub fn pop_wait(&self, timeout: Duration) -> Option<ServeRequest> {
        let g = self.inner.lock().unwrap();
        let (mut g, _) = self
            .cv
            .wait_timeout_while(g, timeout, |i| {
                i.total == 0 && !i.draining
            })
            .unwrap();
        g.pop_rr()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().total
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Queued depth per tenant lane (the `serve.queued.<tenant>` gauges
    /// and the `/healthz` breakdown).  Lanes a tenant has touched stay
    /// listed at 0 so the gauge series doesn't vanish between bursts.
    pub fn depths(&self) -> Vec<(String, usize)> {
        self.inner
            .lock()
            .unwrap()
            .lanes
            .iter()
            .map(|(n, l)| (n.clone(), l.len()))
            .collect()
    }

    /// Refuse new admissions; everything already queued still runs.
    pub fn begin_drain(&self) {
        self.inner.lock().unwrap().draining = true;
        self.cv.notify_all();
    }

    pub fn is_draining(&self) -> bool {
        self.inner.lock().unwrap().draining
    }
}

/// Shared serving counters: atomics the handlers, the scheduler and
/// `/healthz` all touch without locking (plus one small mutexed map for
/// the per-adapter request counts).
#[derive(Default)]
pub struct ServeStats {
    pub received: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub cancelled: AtomicU64,
    pub tokens_streamed: AtomicU64,
    pub active: AtomicU64,
    pub queued: AtomicU64,
    /// prompt tokens actually run through prefill — prefix-cache hits
    /// are excluded, so `prefilled_tokens` vs received prompt lengths
    /// is the compute the cache saved
    pub prefilled_tokens: AtomicU64,
    /// prefix-cache totals mirrored from [`KvCache::prefix_stats`] by
    /// the scheduler loop (all zero when `--prefix-cache off`)
    pub prefix_hit_blocks: AtomicU64,
    pub prefix_miss_blocks: AtomicU64,
    pub prefix_hit_tokens: AtomicU64,
    pub prefix_evicted: AtomicU64,
    pub prefix_pool_blocks: AtomicU64,
    pub prefix_shared_blocks: AtomicU64,
    per_adapter: Mutex<BTreeMap<String, u64>>,
}

impl ServeStats {
    pub fn count_adapter(&self, name: &str) {
        let mut g = self.per_adapter.lock().unwrap();
        *g.entry(name.to_string()).or_insert(0) += 1;
    }

    pub fn adapter_counts(&self) -> BTreeMap<String, u64> {
        self.per_adapter.lock().unwrap().clone()
    }
}

/// One in-flight sequence: its cache slot, its channel back to the
/// handler, and its private sampling stream.
struct Active {
    slot: usize,
    req: ServeRequest,
    rng: Rng,
    last: i32,
    n_gen: usize,
}

/// A request mid-prefill: it owns a cache slot and has cached
/// `done` prompt tokens so far; the next chunk continues from there.
struct Prefilling {
    slot: usize,
    req: ServeRequest,
    done: usize,
    /// prompt positions spliced from the prefix cache at admission —
    /// `done` starts here, and only `prompt.len() - reused` tokens
    /// ever run through prefill
    reused: usize,
}

/// The continuous-batching loop.  Owns the KV cache; borrows the
/// runtime, the ONE shared base `ParamSource` and the adapter registry
/// for its lifetime — per-request state never includes parameters,
/// which is the zero-base-duplication invariant.
pub struct Scheduler<'a> {
    rt: &'a dyn InferRuntime,
    base: &'a dyn ParamSource,
    adapters: &'a BTreeMap<String, AdapterSet>,
    cache: KvCache,
    active: Vec<Active>,
    /// admitted requests whose prompts are still being prefilled,
    /// advanced one `prefill_chunk` per loop iteration in FIFO order
    prefilling: VecDeque<Prefilling>,
    /// prompt tokens prefilled per iteration; 0 = whole prompt at once
    prefill_chunk: usize,
}

impl<'a> Scheduler<'a> {
    /// `cache` fixes the batch ceiling (`--max-batch` slots) and the
    /// per-sequence context capacity (`--max-context`).
    pub fn new(rt: &'a dyn InferRuntime, base: &'a dyn ParamSource,
               adapters: &'a BTreeMap<String, AdapterSet>, cache: KvCache)
        -> Scheduler<'a> {
        Scheduler {
            rt,
            base,
            adapters,
            cache,
            active: Vec::new(),
            prefilling: VecDeque::new(),
            prefill_chunk: 0,
        }
    }

    /// Prefill prompts `chunk` tokens at a time (`--prefill-chunk`),
    /// interleaved with decode steps; 0 keeps monolithic prefill.  The
    /// token streams are identical either way — chunking only bounds
    /// how long peers wait between their own tokens.
    pub fn with_prefill_chunk(mut self, chunk: usize) -> Scheduler<'a> {
        self.prefill_chunk = chunk;
        self
    }

    /// Serve until `queue` is draining and no work remains.  Everything
    /// admitted or queued before the drain began runs to completion.
    pub fn run(&mut self, queue: &Queue, stats: &ServeStats) {
        loop {
            // prefilling requests hold slots too — don't over-admit
            while self.active.len() + self.prefilling.len()
                < self.cache.batch
            {
                match queue.try_pop() {
                    Some(r) => self.admit(r, stats),
                    None => break,
                }
            }
            stats.queued.store(queue.len() as u64, Ordering::Relaxed);
            stats
                .active
                .store(self.active.len() as u64, Ordering::Relaxed);
            if obs::enabled() {
                obs::gauge("serve.queue_depth", queue.len() as f64);
                obs::gauge("serve.active", self.active.len() as f64);
                for (tenant, depth) in queue.depths() {
                    obs::gauge(&format!("serve.queued.{tenant}"),
                               depth as f64);
                }
                obs::gauge("serve.kv_blocks_live",
                           self.cache.blocks_live() as f64);
                obs::gauge("serve.kv_blocks_free",
                           self.cache.blocks_free() as f64);
                obs::gauge("serve.kv_bytes", self.cache.bytes() as f64);
            }
            let ps = self.cache.prefix_stats();
            if ps.enabled {
                stats.prefix_hit_blocks
                    .store(ps.hit_blocks, Ordering::Relaxed);
                stats.prefix_miss_blocks
                    .store(ps.miss_blocks, Ordering::Relaxed);
                stats.prefix_hit_tokens
                    .store(ps.hit_tokens, Ordering::Relaxed);
                stats.prefix_evicted
                    .store(ps.evicted, Ordering::Relaxed);
                stats.prefix_pool_blocks
                    .store(ps.pool_blocks as u64, Ordering::Relaxed);
                stats.prefix_shared_blocks
                    .store(ps.shared_blocks as u64, Ordering::Relaxed);
                if obs::enabled() {
                    obs::gauge("serve.prefix_pool_blocks",
                               ps.pool_blocks as f64);
                    obs::gauge("serve.prefix_shared_blocks",
                               ps.shared_blocks as f64);
                    obs::gauge("serve.prefix_pool_bytes",
                               self.cache.prefix_pool_bytes() as f64);
                }
            }
            if self.active.is_empty() && self.prefilling.is_empty() {
                if queue.is_draining() && queue.is_empty() {
                    break;
                }
                if let Some(r) =
                    queue.pop_wait(Duration::from_millis(50))
                {
                    self.admit(r, stats);
                }
                continue;
            }
            // one prefill chunk, then one decode step: an in-flight
            // peer waits at most one chunk of forward work per token
            self.advance_prefill(stats);
            self.step(stats);
        }
    }

    /// Admit one request: validate it, claim a cache slot and park it on
    /// the prefill queue (its first chunk runs on the next loop
    /// iteration).  Any failure is reported on the request's channel and
    /// never disturbs the rest of the batch.
    fn admit(&mut self, req: ServeRequest, stats: &ServeStats) {
        if let Some(name) = &req.adapter {
            if !self.adapters.contains_key(name) {
                let _ = req.tx.send(TokenEvent::Error(format!(
                    "unknown adapter {name:?}")));
                stats.rejected.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
        if req.prompt.is_empty()
            || req.prompt.len() > self.cache.capacity
        {
            let _ = req.tx.send(TokenEvent::Error(format!(
                "prompt of {} tokens outside 1..={}",
                req.prompt.len(), self.cache.capacity)));
            stats.rejected.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let Some(slot) = self.cache.acquire() else {
            // active + prefilling < cache.batch implies a free slot;
            // report rather than trusting the invariant with a panic
            let _ = req.tx.send(TokenEvent::Error(
                "no free cache slot".to_string()));
            stats.rejected.fetch_add(1, Ordering::Relaxed);
            return;
        };
        // splice the longest cached prefix (tenant-namespaced) into the
        // fresh slot; prefill resumes from the first uncached position.
        // A strict no-op returning 0 with `--prefix-cache off`.
        let tenant = req.adapter.as_deref().unwrap_or("base");
        let reused = self.cache.admit_prefix(slot, tenant, &req.prompt);
        if self.cache.prefix_enabled() && obs::enabled() {
            let blk = self.cache.block;
            let eligible = req.prompt.len().saturating_sub(1) / blk;
            obs::add("serve.prefix_hit_blocks", (reused / blk) as u64);
            obs::add("serve.prefix_miss_blocks",
                     (eligible - reused / blk) as u64);
            obs::add("serve.prefix_hit_tokens", reused as u64);
        }
        self.prefilling
            .push_back(Prefilling { slot, req, done: reused, reused });
    }

    /// Advance the oldest pending prefill by one chunk; on the last
    /// chunk, sample + stream the first token and move the request into
    /// the decode batch.  Chunked and monolithic prefill produce the
    /// same cached K/V and the same final-position logits (each
    /// position's forward depends only on itself and earlier positions),
    /// so the resulting token stream is pinned identical.
    fn advance_prefill(&mut self, stats: &ServeStats) {
        let Some(mut p) = self.prefilling.pop_front() else {
            return;
        };
        let chunk = if self.prefill_chunk == 0 {
            p.req.prompt.len()
        } else {
            self.prefill_chunk
        };
        let hi = (p.done + chunk).min(p.req.prompt.len());
        let adapter = p.req.adapter.as_deref()
            .and_then(|n| self.adapters.get(n));
        let sp = obs::span("serve", "prefill");
        let logits = match self.rt.prefill_adapted(
            self.base, adapter, &mut self.cache, p.slot,
            &p.req.prompt[p.done..hi])
        {
            Ok(l) => l,
            Err(e) => {
                self.cache.release(p.slot);
                let _ = p.req.tx
                    .send(TokenEvent::Error(format!("prefill: {e}")));
                stats.rejected.fetch_add(1, Ordering::Relaxed);
                return;
            }
        };
        sp.done();
        // record the freshly cached tokens so full blocks seal (and
        // become shareable) as soon as their last position lands
        self.cache.note_tokens(p.slot, &p.req.prompt[p.done..hi]);
        p.done = hi;
        if p.done < p.req.prompt.len() {
            // more chunks to go; intermediate logits are discarded
            self.prefilling.push_front(p);
            return;
        }
        let req = p.req;
        let slot = p.slot;
        let prefilled = (req.prompt.len() - p.reused) as u64;
        stats.prefilled_tokens.fetch_add(prefilled, Ordering::Relaxed);
        if obs::enabled() {
            obs::hist_record(
                "serve.ttft_us",
                1e6 * req.enqueued.elapsed().as_secs_f64());
            obs::add("serve.prefill_tokens", prefilled);
            let tenant = req.adapter.as_deref().unwrap_or("base");
            obs::add(&format!("serve.requests.{tenant}"), 1);
        }
        stats.count_adapter(req.adapter.as_deref().unwrap_or("base"));
        // same stream as a solo `generate` run at sequence index 0, so
        // serve output is reproducible outside the server
        let mut rng = Rng::new(req.spec.seed).fork(0);
        let tok = req.spec.sampler.sample(&logits, &mut rng) as i32;
        if req.tx.send(TokenEvent::Token(tok)).is_err() {
            // client hung up between enqueue and first token
            self.cache.release(slot);
            stats.cancelled.fetch_add(1, Ordering::Relaxed);
            return;
        }
        stats.tokens_streamed.fetch_add(1, Ordering::Relaxed);
        obs::add("serve.tokens_streamed", 1);
        let a = Active { slot, req, rng, last: tok, n_gen: 1 };
        if a.req.spec.stop_tokens.contains(&tok) {
            self.finish(a, FinishReason::Stop, stats);
        } else if a.req.spec.max_new <= 1 {
            self.finish(a, FinishReason::Length, stats);
        } else {
            // decode lists sequences in increasing slot order
            let at = self
                .active
                .partition_point(|x| x.slot < a.slot);
            self.active.insert(at, a);
        }
    }

    /// One batched decode step over every active sequence.
    fn step(&mut self, stats: &ServeStats) {
        // a sequence whose slot is full cannot take another step:
        // retire it cleanly instead of aborting the batch
        let mut i = 0;
        while i < self.active.len() {
            if self.cache.len(self.active[i].slot) >= self.cache.capacity
            {
                let a = self.active.remove(i);
                self.finish(a, FinishReason::ContextFull, stats);
            } else {
                i += 1;
            }
        }
        if self.active.is_empty() {
            return;
        }
        let seqs: Vec<usize> =
            self.active.iter().map(|a| a.slot).collect();
        let toks: Vec<i32> = self.active.iter().map(|a| a.last).collect();
        let ovs: Vec<Option<&AdapterSet>> = self
            .active
            .iter()
            .map(|a| {
                a.req.adapter.as_deref().and_then(|n| self.adapters.get(n))
            })
            .collect();
        let sp = obs::span("serve", "decode");
        let batch = self.active.len();
        let logits = match self.rt.decode_adapted(
            self.base, &ovs, &mut self.cache, &seqs, &toks)
        {
            Ok(l) => l,
            Err(e) => {
                // a failed step poisons every listed sequence: fail
                // them all and keep serving new admissions
                let msg = format!("decode: {e}");
                for a in std::mem::take(&mut self.active) {
                    self.cache.release(a.slot);
                    let _ =
                        a.req.tx.send(TokenEvent::Error(msg.clone()));
                    stats.rejected.fetch_add(1, Ordering::Relaxed);
                }
                return;
            }
        };
        let secs = sp.done();
        // each sequence just cached its fed token's K/V: extend the
        // recorded histories so generated text seals blocks too (a
        // follow-up turn quoting this reply can then hit the cache)
        for (s, t) in seqs.iter().zip(&toks) {
            self.cache.note_tokens(*s, &[*t]);
        }
        if obs::enabled() {
            obs::hist_record("serve.decode_token_us",
                             1e6 * secs / batch as f64);
        }
        let v = self.rt.vocab_out();
        let mut still = Vec::with_capacity(batch);
        for (i, mut a) in
            std::mem::take(&mut self.active).into_iter().enumerate()
        {
            let row = &logits[i * v..(i + 1) * v];
            let tok = a.req.spec.sampler.sample(row, &mut a.rng) as i32;
            a.last = tok;
            a.n_gen += 1;
            if a.req.tx.send(TokenEvent::Token(tok)).is_err() {
                // client went away mid-stream: reclaim its slot now
                self.cache.release(a.slot);
                stats.cancelled.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            stats.tokens_streamed.fetch_add(1, Ordering::Relaxed);
            obs::add("serve.tokens_streamed", 1);
            if a.req.spec.stop_tokens.contains(&tok) {
                self.finish(a, FinishReason::Stop, stats);
            } else if a.n_gen >= a.req.spec.max_new {
                self.finish(a, FinishReason::Length, stats);
            } else {
                still.push(a);
            }
        }
        self.active = still;
    }

    fn finish(&mut self, a: Active, finish: FinishReason,
              stats: &ServeStats) {
        self.cache.release(a.slot);
        let _ = a.req.tx.send(TokenEvent::Done {
            finish,
            n_generated: a.n_gen,
        });
        stats.completed.fetch_add(1, Ordering::Relaxed);
        if obs::enabled() {
            obs::hist_record(
                "serve.request_us",
                1e6 * a.req.enqueued.elapsed().as_secs_f64());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn dummy_request(id: u64, tx: Sender<TokenEvent>) -> ServeRequest {
        tenant_request(id, None, tx)
    }

    fn tenant_request(id: u64, adapter: Option<&str>,
                      tx: Sender<TokenEvent>) -> ServeRequest {
        ServeRequest {
            id,
            adapter: adapter.map(str::to_string),
            prompt: vec![1, 2, 3],
            spec: SamplingSpec {
                sampler: Sampler::greedy(),
                seed: 1,
                max_new: 4,
                stop_tokens: Vec::new(),
            },
            tx,
            enqueued: Instant::now(),
        }
    }

    #[test]
    fn queue_backpressure_and_drain() {
        let q = Queue::new(2);
        let (tx, _rx) = channel();
        assert_eq!(q.push(dummy_request(1, tx.clone())),
                   Admission::Queued);
        assert_eq!(q.push(dummy_request(2, tx.clone())),
                   Admission::Queued);
        assert_eq!(q.push(dummy_request(3, tx.clone())), Admission::Full);
        assert_eq!(q.len(), 2);
        assert_eq!(q.try_pop().unwrap().id, 1);
        assert_eq!(q.push(dummy_request(4, tx.clone())),
                   Admission::Queued);
        q.begin_drain();
        assert_eq!(q.push(dummy_request(5, tx.clone())),
                   Admission::Draining);
        // already-queued work survives the drain
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop_wait(Duration::from_millis(1)).unwrap().id, 2);
        assert_eq!(q.try_pop().unwrap().id, 4);
        // draining + empty: the idle wait returns immediately
        let t0 = Instant::now();
        assert!(q.pop_wait(Duration::from_secs(5)).is_none());
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn queue_round_robins_across_tenants() {
        // a chatty tenant fills the queue but dequeue order interleaves
        // every waiting tenant: one request each per rotation
        let q = Queue::new(16);
        let (tx, _rx) = channel();
        for id in [1, 2, 3] {
            q.push(tenant_request(id, Some("chatty"), tx.clone()));
        }
        q.push(tenant_request(10, Some("quiet"), tx.clone()));
        q.push(tenant_request(20, None, tx.clone())); // "base" lane
        q.push(tenant_request(11, Some("quiet"), tx.clone()));
        let depths = q.depths();
        assert_eq!(depths, vec![("chatty".to_string(), 3),
                                ("quiet".to_string(), 2),
                                ("base".to_string(), 1)]);
        let order: Vec<u64> =
            (0..6).map(|_| q.try_pop().unwrap().id).collect();
        // rotation 1: chatty, quiet, base; rotation 2: chatty, quiet;
        // rotation 3: chatty — FIFO within each lane throughout
        assert_eq!(order, vec![1, 10, 20, 2, 11, 3]);
        assert!(q.try_pop().is_none());
        // drained lanes stay listed at depth 0 for the gauges
        assert_eq!(q.depths(), vec![("chatty".to_string(), 0),
                                    ("quiet".to_string(), 0),
                                    ("base".to_string(), 0)]);
    }

    #[test]
    fn stats_track_per_adapter_counts() {
        let s = ServeStats::default();
        s.count_adapter("a");
        s.count_adapter("b");
        s.count_adapter("a");
        let c = s.adapter_counts();
        assert_eq!(c.get("a"), Some(&2));
        assert_eq!(c.get("b"), Some(&1));
    }
}
