//! GLUE-analog full fine-tuning (Tables 7/8 substitute).
//!
//! Protocol mirrors the paper's Section 4.4: take a pre-trained checkpoint;
//! if it was trained with (Switch)LoRA, merge every adapter into the base
//! weights (`W ← W + s·BA`); then **full** fine-tune a classification head
//! variant on each downstream task and report accuracy.

use anyhow::{Context, Result};

use crate::coordinator::eval::eval_cls;
use crate::data::tasks::{Task, TaskGen};
use crate::model::init::BASE_STD;
use crate::model::layout::{Manifest, ParamStore, Variant};
use crate::optim::adam::AdamState;
use crate::optim::schedule::LrSchedule;
use crate::optim::AdamHyper;
use crate::runtime::{Engine, ModelRuntime};
use crate::tensor::matmul::matmul;
use crate::util::rng::Rng;

/// Merge all LoRA adapters of a lora-layout store into its base weights,
/// producing the effective full-rank weights (paper: "all LoRA adapters are
/// merged into the original weights ... before the fine-tuning process").
pub fn merge_adapters(store: &mut ParamStore, manifest: &Manifest) {
    let scale = manifest.config.lora_scale() as f32;
    for li in &manifest.linears {
        let a = store.tensor(&li.a).expect("A");
        let b = store.tensor(&li.b).expect("B");
        let mut ba = matmul(&b, &a);
        ba.scale(scale);
        let w = store.slice_mut(&li.name).expect("W");
        for (wi, d) in w.iter_mut().zip(&ba.data) {
            *wi += d;
        }
        // zero the adapters so a later re-merge is a no-op
        store.slice_mut(&li.b).expect("B").fill(0.0);
    }
}

/// Build a cls-variant store from a pre-trained store (lora or full),
/// merging adapters if needed and freshly initializing the class head.
pub fn to_cls_store(pretrained: &ParamStore, from_variant: Variant,
                    manifest: &Manifest, seed: u64) -> Result<ParamStore> {
    let mut src = pretrained.clone();
    if from_variant == Variant::Lora {
        merge_adapters(&mut src, manifest);
    }
    let cls_layout = std::sync::Arc::new(
        manifest.layout(Variant::Cls)?.clone());
    let mut dst = ParamStore::zeros(cls_layout);
    let copied = crate::model::init::copy_shared(&src, &mut dst);
    anyhow::ensure!(copied > 0, "no parameters carried into cls store");
    // fresh classification head
    let mut rng = Rng::new(seed ^ 0xC15);
    let head = dst.slice_mut("cls_head").context("cls_head")?;
    for x in head.iter_mut() {
        *x = rng.normal_f32(0.0, BASE_STD);
    }
    Ok(dst)
}

#[derive(Clone, Debug)]
pub struct FinetuneResult {
    pub task: Task,
    pub accuracy: f32,
    pub loss: f32,
    pub steps: u64,
}

/// Full fine-tuning of a cls store on one task; returns held-out accuracy.
#[allow(clippy::too_many_arguments)]
pub fn finetune_task(engine: &mut Engine, manifest: &Manifest,
                     cls_store: &mut ParamStore, task: Task, steps: u64,
                     lr: f32, seed: u64, eval_examples: usize)
    -> Result<FinetuneResult> {
    let mc = &manifest.config;
    let rt = ModelRuntime::load(engine, manifest.clone(), Variant::Cls)?;
    let layout = cls_store.layout.clone();
    let padded = rt.padded;
    let mut opt = AdamState::new(layout.n_trainable, padded);
    let mut mask = vec![0.0f32; padded];
    for x in mask.iter_mut().take(layout.n_trainable) {
        *x = 1.0;
    }
    let sched = LrSchedule::cosine(lr, (steps / 10).max(1), steps);
    let mut gen = TaskGen::new(task, mc.vocab, mc.seq, seed);
    // held-out eval batches (disjoint stream: different seed)
    let mut eval_gen = TaskGen::new(task, mc.vocab, mc.seq, seed ^ 0xEEE);
    let n_eval_batches = (eval_examples / mc.batch).max(1);
    let eval_batches: Vec<(Vec<i32>, Vec<i32>)> =
        (0..n_eval_batches).map(|_| eval_gen.batch(mc.batch)).collect();

    for step in 0..steps {
        let (toks, labels) = gen.batch(mc.batch);
        let (loss, grad) =
            rt.cls_fwdbwd(cls_store, &toks, &labels, mc.batch, mc.seq)?;
        let hyper = AdamHyper::new(sched.lr(step));
        let mut flat = cls_store.gather_trainable(padded);
        rt.adam_step(&mut flat, &grad, &mut opt, &mask, &hyper)?;
        cls_store.scatter_trainable(&flat);
        if step % 50 == 0 {
            crate::debuglog!("ft {} step {step} loss {loss:.4}",
                             task.name());
        }
    }
    let (loss, acc) = eval_cls(&rt, cls_store, &eval_batches, mc.seq)?;
    crate::info!("finetune {}: acc {:.3} loss {:.4} ({} steps)",
                 task.name(), acc, loss, steps);
    Ok(FinetuneResult { task, accuracy: acc, loss, steps })
}

/// Fine-tune one pre-trained store on a suite of tasks (Table 7/8 row).
#[allow(clippy::too_many_arguments)]
pub fn glue_suite(engine: &mut Engine, manifest: &Manifest,
                  pretrained: &ParamStore, from_variant: Variant,
                  tasks: &[Task], steps: u64, lr: f32, seed: u64)
    -> Result<Vec<FinetuneResult>> {
    let mut out = Vec::new();
    for &task in tasks {
        // fresh cls store per task (fine-tuning is independent per task)
        let mut cls = to_cls_store(pretrained, from_variant, manifest,
                                   seed)?;
        out.push(finetune_task(engine, manifest, &mut cls, task, steps, lr,
                               seed, 256)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::{init_store, InitMode};

    fn manifest() -> Manifest {
        Manifest::for_spec(
            &crate::coordinator::trainer::default_artifacts_dir(), "tiny")
            .unwrap()
    }

    #[test]
    fn merge_preserves_zero_after() {
        let man = manifest();
        let layout = std::sync::Arc::new(man.lora.clone());
        let mut store = ParamStore::zeros(layout);
        let mut rng = Rng::new(0);
        init_store(&mut store, &man.linears, man.config.rank,
                   InitMode::SwitchLora, &mut rng);
        let w_before = store.tensor(&man.linears[0].name).unwrap();
        merge_adapters(&mut store, &man);
        // B zeroed, W changed
        assert!(store
            .slice(&man.linears[0].b)
            .unwrap()
            .iter()
            .all(|&x| x == 0.0));
        let w_after = store.tensor(&man.linears[0].name).unwrap();
        assert!(w_before.max_abs_diff(&w_after) > 0.0);
        // re-merge is a no-op now
        let w2 = w_after.clone();
        merge_adapters(&mut store, &man);
        assert_eq!(w2.data,
                   store.tensor(&man.linears[0].name).unwrap().data);
    }

    #[test]
    fn cls_store_has_head_and_weights() {
        let man = manifest();
        let layout = std::sync::Arc::new(man.lora.clone());
        let mut store = ParamStore::zeros(layout);
        let mut rng = Rng::new(1);
        init_store(&mut store, &man.linears, man.config.rank,
                   InitMode::SwitchLora, &mut rng);
        let cls = to_cls_store(&store, Variant::Lora, &man, 7).unwrap();
        assert!(cls.layout.meta("cls_head").is_ok());
        assert!(cls.layout.meta("lm_head").is_err());
        // embeddings carried over
        assert_eq!(cls.slice("embed").unwrap(), store.slice("embed").unwrap());
    }
}
