//! Singular-value / rank analysis of trained weights (Figures 10/11 +
//! Appendix E): compute spectra of the *effective* weight `W + s·BA` per
//! linear-layer type and summarize their distribution.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::model::layout::{Manifest, ParamStore, Variant};
use crate::tensor::linalg::{effective_rank, singular_values};
use crate::tensor::matmul::matmul;
use crate::tensor::Tensor;

/// Per-layer-type spectrum summary.
#[derive(Clone, Debug)]
pub struct SpectrumRow {
    pub kind: String,
    pub n_matrices: usize,
    pub s_max_mean: f64,
    pub s_med_mean: f64,
    pub s_min_mean: f64,
    /// mean effective rank at 1% of s_max, normalized by min(m,n)
    pub eff_rank_frac: f64,
    /// mean spread s_max/s_med — the "illness" indicator of Fig. 10
    pub condition: f64,
}

fn kind_of(name: &str) -> Option<&'static str> {
    for k in ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"] {
        if name.ends_with(k) {
            return Some(k);
        }
    }
    None
}

/// Effective weight of one linear (W alone in full variant; W + s·BA in
/// lora variant).
pub fn effective_weight(store: &ParamStore, manifest: &Manifest,
                        variant: Variant, name: &str) -> Result<Tensor> {
    let w = store.tensor(name)?;
    if variant != Variant::Lora {
        return Ok(w);
    }
    let li = manifest
        .linears
        .iter()
        .find(|l| l.name == name)
        .ok_or_else(|| anyhow::anyhow!("{name} is not a LoRA linear"))?;
    let mut ba = matmul(&store.tensor(&li.b)?, &store.tensor(&li.a)?);
    ba.scale(manifest.config.lora_scale() as f32);
    let mut e = w;
    e.axpy(1.0, &ba);
    Ok(e)
}

/// Spectra of every LoRA-adapted linear, grouped by layer type.
pub fn analyze(store: &ParamStore, manifest: &Manifest, variant: Variant)
    -> Result<Vec<SpectrumRow>> {
    let mut groups: BTreeMap<&'static str, Vec<Vec<f32>>> = BTreeMap::new();
    for li in &manifest.linears {
        let Some(kind) = kind_of(&li.name) else { continue };
        let e = effective_weight(store, manifest, variant, &li.name)?;
        groups.entry(kind).or_default().push(singular_values(&e));
    }
    let mut rows = Vec::new();
    for (kind, spectra) in groups {
        let n = spectra.len();
        let mut s_max = 0.0;
        let mut s_med = 0.0;
        let mut s_min = 0.0;
        let mut eff = 0.0;
        let mut cond = 0.0;
        for s in &spectra {
            s_max += s[0] as f64;
            s_med += s[s.len() / 2] as f64;
            s_min += *s.last().unwrap() as f64;
            eff += effective_rank(s, 0.01) as f64 / s.len() as f64;
            cond += s[0] as f64 / (s[s.len() / 2] as f64).max(1e-12);
        }
        let nf = n as f64;
        rows.push(SpectrumRow {
            kind: kind.to_string(),
            n_matrices: n,
            s_max_mean: s_max / nf,
            s_med_mean: s_med / nf,
            s_min_mean: s_min / nf,
            eff_rank_frac: eff / nf,
            condition: cond / nf,
        });
    }
    Ok(rows)
}

pub fn table(rows: &[SpectrumRow]) -> String {
    let mut s = format!(
        "{:<8} {:>4} {:>10} {:>10} {:>10} {:>10} {:>10}\n",
        "layer", "n", "s_max", "s_med", "s_min", "eff_rank%", "s_max/s_med");
    for r in rows {
        s.push_str(&format!(
            "{:<8} {:>4} {:>10.4} {:>10.4} {:>10.4} {:>10.1} {:>10.2}\n",
            r.kind, r.n_matrices, r.s_max_mean, r.s_med_mean, r.s_min_mean,
            100.0 * r.eff_rank_frac, r.condition));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::{init_store, InitMode};
    use crate::util::rng::Rng;

    #[test]
    fn analyze_random_init() {
        let man = Manifest::for_spec(
            &crate::coordinator::trainer::default_artifacts_dir(), "tiny")
            .unwrap();
        let layout = std::sync::Arc::new(man.lora.clone());
        let mut store = ParamStore::zeros(layout);
        let mut rng = Rng::new(0);
        init_store(&mut store, &man.linears, man.config.rank,
                   InitMode::SwitchLora, &mut rng);
        let rows = analyze(&store, &man, Variant::Lora).unwrap();
        assert_eq!(rows.len(), 7); // wq wk wv wo gate up down
        // At init the Eq. (3)-scaled adapter dominates the 0.02-std base
        // weights, so the effective-weight spectrum has at least the
        // adapter's rank r of large singular values out of min(m,n).
        let r_frac = man.config.rank as f64
            / man.config.hidden.min(man.config.ff) as f64;
        for r in rows {
            assert!(r.s_max_mean > 0.0);
            assert!(r.eff_rank_frac >= 0.8 * r_frac.min(1.0),
                    "{}: {} < {}", r.kind, r.eff_rank_frac, r_frac);
            assert!(r.s_max_mean >= r.s_med_mean
                    && r.s_med_mean >= r.s_min_mean);
        }
        assert!(!table(&analyze(&store, &man, Variant::Lora).unwrap())
            .is_empty());
    }

    #[test]
    fn kind_classification() {
        assert_eq!(kind_of("l3.wq"), Some("wq"));
        assert_eq!(kind_of("l0.w_down"), Some("w_down"));
        assert_eq!(kind_of("embed"), None);
    }
}
