//! Experiment drivers: one entry point per paper table/figure, shared by
//! the examples, the CLI and the bench targets.

pub mod finetune;
pub mod rank;

use anyhow::Result;

use crate::coordinator::trainer::{Method, RunResult, TrainConfig, Trainer};
use crate::runtime::Engine;

/// Run one pre-training configuration and return its result + final store.
pub fn pretrain(engine: &mut Engine, cfg: TrainConfig)
    -> Result<(RunResult, crate::model::layout::ParamStore)> {
    let t = Trainer::new(cfg)?;
    t.run(engine)
}

/// Compare several methods on one spec (Figure 2/3 + Table 2/3 analog).
pub fn compare_methods(engine: &mut Engine, spec: &str, steps: u64,
                       methods: &[Method], out_dir: &std::path::Path,
                       workers: usize) -> Result<Vec<RunResult>> {
    let mut out = Vec::new();
    for m in methods {
        let mut cfg = TrainConfig::new(spec, m.clone(), steps);
        cfg.workers = workers;
        cfg.metrics_csv = Some(out_dir.join(format!(
            "{spec}_{}.csv", m.name())));
        let (res, _) = pretrain(engine, cfg)?;
        crate::info!("{spec}/{}: final eval loss {:.4} ppl {:.2}",
                     res.method, res.final_eval_loss, res.final_ppl);
        out.push(res);
    }
    Ok(out)
}

/// Render a compact results table (printed by examples and benches).
pub fn results_table(title: &str, rows: &[RunResult]) -> String {
    let mut s = format!("\n== {title} ==\n");
    s.push_str(&format!(
        "{:<12} {:<10} {:>10} {:>8} {:>12} {:>12} {:>10}\n",
        "method", "spec", "eval_loss", "ppl", "trainable",
        "comm_bytes", "step_ms"));
    for r in rows {
        s.push_str(&format!(
            "{:<12} {:<10} {:>10.4} {:>8.2} {:>12} {:>12} {:>10.1}\n",
            r.method, r.spec, r.final_eval_loss, r.final_ppl,
            crate::util::human_params(r.n_trainable as u64),
            crate::util::human_bytes(r.comm.bytes), r.mean_step_ms));
    }
    s
}
