#!/usr/bin/env python3
"""Fill the TTFT/ITL fields of the committed BENCH_serve.json with
honest timings when no Rust toolchain is available.

The canonical way to (re)generate the report is
`cargo bench --bench bench_serve -- --json BENCH_serve.json`.  This
script exists for environments that can compile C but not Rust.  The
seed report (tools/seed_bench_serve.py) transliterated only the
connection fast-path and therefore OMITTED `serve_ttft_ms` /
`serve_itl_ms_per_tok`; this script closes that gap by transliterating
the model compute those metrics are dominated by: the tiny-spec
`forward_cached` loop from rust/src/runtime/native.rs — embedding
lookup, per-layer RMSNorm, q/k/v/o projections, RoPE, softmax
attention over the KV cache, SiLU-gated MLP, final norm, and the
chunk-final lm_head row — in plain f32 C at the exact same dimensions
(vocab 256, hidden 64, layers 2, heads 4, head_dim 16, ff 128) and the
serve defaults (prefill chunk 32, KV block 32).  Compiled with
`gcc -O2` (no -ffast-math: the Rust build does strict IEEE too) and
timed as the min over repetitions.

Measured figures:
  * serve_ttft_ms        — cold chunked prefill of a 64-token prompt
                           (the prompt bench_serve.rs times);
  * serve_itl_ms_per_tok — mean single-token decode step at ~200 ctx;
  * serve_ttft_cold_us   — cold chunked prefill of a 193-token prompt;
  * serve_ttft_warm_us   — the same prompt with its first 160 positions
                           (5 whole 32-position blocks) already cached:
                           the prefix-warm path prefills only the
                           33-token suffix.
The prefill-token counts in the `prefix_warm` table are exact
arithmetic (193 cold vs 33 warm, 160 spliced), the same numbers the
scheduler's `prefilled_tokens` counter reports.  What this
transliteration cannot include is the HTTP/scheduler overhead between
socket write and first compute (~1/serve_keepalive_req_s, about 0.1 ms
on the seed host) — the note in the JSON says so.  stdlib only.
"""

import json
import os
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

C_SRC = r"""
#include <math.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <time.h>

/* tiny spec, as rust/src/model/config.rs presets it */
#define V 256
#define H 64
#define L 2
#define NH 4
#define HD 16
#define FF 128
#define CAP 256
#define CHUNK 32  /* serve --prefill-chunk default */

static float embed[V * H], lm_head[V * H];
static float attn_norm[L][H], mlp_norm[L][H], final_norm[H];
static float wq[L][H * H], wk[L][H * H], wv[L][H * H], wo[L][H * H];
static float wg[L][FF * H], wu[L][FF * H], wd[L][H * FF];
/* per-layer KV cache, [head][pos][hd] like infer/kv_cache.rs views it */
static float kc[L][NH][CAP][HD], vc[L][NH][CAP][HD];

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec + ts.tv_nsec / 1e9;
}

static unsigned long long rng = 0x9e3779b97f4a7c15ULL;
static float frand(void) {
    rng ^= rng << 13; rng ^= rng >> 7; rng ^= rng << 17;
    return (float)((double)(rng >> 11) / 9007199254740992.0 - 0.5);
}

static void fill(float *p, int n) {
    for (int i = 0; i < n; i++) p[i] = 0.1f * frand();
}

/* y[t][o] = sum_i w[o*in + i] * x[t*in + i] — linear_fwd's loop */
static void linear(const float *x, const float *w, float *y, int t,
                   int in, int out) {
    for (int r = 0; r < t; r++)
        for (int o = 0; o < out; o++) {
            float acc = 0.0f;
            const float *xr = x + r * in, *wr = w + o * in;
            for (int i = 0; i < in; i++) acc += wr[i] * xr[i];
            y[r * out + o] = acc;
        }
}

static void rmsnorm(const float *x, const float *g, float *y, int t,
                    int h) {
    for (int r = 0; r < t; r++) {
        float ss = 0.0f;
        for (int i = 0; i < h; i++) ss += x[r * h + i] * x[r * h + i];
        float inv = 1.0f / sqrtf(ss / h + 1e-6f);
        for (int i = 0; i < h; i++)
            y[r * h + i] = x[r * h + i] * inv * g[i];
    }
}

static void rope(float *v, int pos) { /* one head row, length HD */
    for (int d = 0; d < HD / 2; d++) {
        float theta = (float)pos
            * powf(10000.0f, -2.0f * (float)d / (float)HD);
        float c = cosf(theta), s = sinf(theta);
        float a = v[2 * d], b = v[2 * d + 1];
        v[2 * d] = a * c - b * s;
        v[2 * d + 1] = a * s + b * c;
    }
}

/* forward_cached for `t` tokens starting at absolute position `base`;
   writes the final position's hidden state into xf_last */
static void forward(const int *toks, int t, int base, float *xf_last) {
    static float x[CHUNK * H], xn[CHUNK * H], y[CHUNK * H];
    static float q[CHUNK * H], k[CHUNK * H], v[CHUNK * H];
    static float gate[CHUNK * FF], up[CHUNK * FF], o[CHUNK * H];
    for (int i = 0; i < t; i++)
        memcpy(x + i * H, embed + toks[i] * H, H * sizeof(float));
    for (int li = 0; li < L; li++) {
        rmsnorm(x, attn_norm[li], xn, t, H);
        linear(xn, wq[li], q, t, H, H);
        linear(xn, wk[li], k, t, H, H);
        linear(xn, wv[li], v, t, H, H);
        for (int i = 0; i < t; i++)
            for (int h = 0; h < NH; h++) {
                rope(q + i * H + h * HD, base + i);
                rope(k + i * H + h * HD, base + i);
                memcpy(kc[li][h][base + i], k + i * H + h * HD,
                       HD * sizeof(float));
                memcpy(vc[li][h][base + i], v + i * H + h * HD,
                       HD * sizeof(float));
            }
        /* causal softmax attention over the cache */
        for (int i = 0; i < t; i++)
            for (int h = 0; h < NH; h++) {
                int ctx = base + i + 1;
                static float sc[CAP];
                const float *qi = q + i * H + h * HD;
                float mx = -1e30f;
                for (int j = 0; j < ctx; j++) {
                    float acc = 0.0f;
                    for (int d = 0; d < HD; d++)
                        acc += qi[d] * kc[li][h][j][d];
                    sc[j] = acc / sqrtf((float)HD);
                    if (sc[j] > mx) mx = sc[j];
                }
                float den = 0.0f;
                for (int j = 0; j < ctx; j++) {
                    sc[j] = expf(sc[j] - mx);
                    den += sc[j];
                }
                float *oi = o + i * H + h * HD;
                memset(oi, 0, HD * sizeof(float));
                for (int j = 0; j < ctx; j++) {
                    float w8 = sc[j] / den;
                    for (int d = 0; d < HD; d++)
                        oi[d] += w8 * vc[li][h][j][d];
                }
            }
        linear(o, wo[li], y, t, H, H);
        for (int i = 0; i < t * H; i++) x[i] += y[i];
        rmsnorm(x, mlp_norm[li], xn, t, H);
        linear(xn, wg[li], gate, t, H, FF);
        linear(xn, wu[li], up, t, H, FF);
        for (int i = 0; i < t * FF; i++)
            gate[i] = gate[i] / (1.0f + expf(-gate[i])) * up[i];
        linear(gate, wd[li], y, t, FF, H);
        for (int i = 0; i < t * H; i++) x[i] += y[i];
    }
    rmsnorm(x + (t - 1) * H, final_norm, xf_last, 1, H);
}

/* chunked prefill from `base`; returns final-chunk argmax like the
   scheduler's first sampled token (greedy) */
static int prefill(const int *toks, int n, int base) {
    float xf[H], logits[V];
    for (int at = 0; at < n; at += CHUNK) {
        int t = (n - at) < CHUNK ? (n - at) : CHUNK;
        forward(toks + at, t, base + at, xf);
        linear(xf, lm_head, logits, 1, H, V); /* chunk-final row */
    }
    int best = 0;
    for (int i = 1; i < V; i++) if (logits[i] > logits[best]) best = i;
    return best;
}

int main(void) {
    fill(embed, V * H); fill(lm_head, V * H); fill(final_norm, H);
    for (int l = 0; l < L; l++) {
        fill(attn_norm[l], H); fill(mlp_norm[l], H);
        fill(wq[l], H * H); fill(wk[l], H * H); fill(wv[l], H * H);
        fill(wo[l], H * H);
        fill(wg[l], FF * H); fill(wu[l], FF * H); fill(wd[l], H * FF);
    }
    int toks[CAP];
    for (int i = 0; i < CAP; i++) toks[i] = (i + 75) % 200;
    const int PLEN = 193, REUSED = 160, SHORT = 64, REPS = 30;

    double t64 = 1e30, cold = 1e30, warm = 1e30;
    int sink = 0;
    for (int r = 0; r < REPS; r++) {
        double t0 = now_s();
        sink += prefill(toks, SHORT, 0);
        double dt = now_s() - t0;
        if (dt < t64) t64 = dt;
    }
    for (int r = 0; r < REPS; r++) {
        double t0 = now_s();
        sink += prefill(toks, PLEN, 0);
        double dt = now_s() - t0;
        if (dt < cold) cold = dt;
    }
    /* warm path: positions 0..REUSED are spliced from sealed blocks —
       no recompute, the suffix attends over the cached rows (which the
       last cold rep left populated, bit-identical to a recompute) */
    for (int r = 0; r < REPS; r++) {
        double t0 = now_s();
        sink += prefill(toks + REUSED, PLEN - REUSED, REUSED);
        double dt = now_s() - t0;
        if (dt < warm) warm = dt;
    }
    /* decode: single-token steps at ~PLEN context */
    int t = prefill(toks, PLEN, 0);
    double d0 = now_s();
    int steps = 32;
    for (int s = 0; s < steps; s++) {
        int one[1] = { t };
        t = prefill(one, 1, PLEN + s);
        sink += t;
    }
    double itl = (now_s() - d0) / steps;
    printf("{\"sink\":%d,\"ttft64_ms\":%.6f,\"ttft_cold_us\":%.3f,"
           "\"ttft_warm_us\":%.3f,\"itl_ms\":%.6f}\n",
           sink, 1e3 * t64, 1e6 * cold, 1e6 * warm, 1e3 * itl);
    return 0;
}
"""


def main():
    bench_path = os.path.join(REPO, "BENCH_serve.json")
    with open(bench_path, "r", encoding="utf-8") as f:
        report = json.load(f)
    with tempfile.TemporaryDirectory() as td:
        src = os.path.join(td, "prefix_bench.c")
        exe = os.path.join(td, "prefix_bench")
        with open(src, "w", encoding="utf-8") as f:
            f.write(C_SRC)
        subprocess.run(["gcc", "-O2", "-o", exe, src, "-lm"],
                       check=True)
        out = subprocess.run([exe], check=True, capture_output=True,
                             text=True).stdout
    m = json.loads(out)
    tracked = report.setdefault("tracked", {})
    tracked["serve_ttft_ms"] = round(m["ttft64_ms"], 3)
    tracked["serve_itl_ms_per_tok"] = round(m["itl_ms"], 3)
    tracked["serve_ttft_cold_us"] = round(m["ttft_cold_us"], 1)
    tracked["serve_ttft_warm_us"] = round(m["ttft_warm_us"], 1)
    # token counts are exact arithmetic: (193-1)//32*32 = 160 spliced
    report["prefix_warm"] = [
        {"phase": "cold",
         "ttft_us": tracked["serve_ttft_cold_us"],
         "prefilled_tokens": 193},
        {"phase": "warm",
         "ttft_us": tracked["serve_ttft_warm_us"],
         "prefilled_tokens": 33,
         "prefix_hit_tokens": 160},
    ]
    report["note"] = (
        report.get("note", "").rstrip() + " TTFT/ITL figures are the "
        "min-of-30 timings of tools/seed_bench_prefix.py's C "
        "transliteration of runtime/native.rs forward_cached at the "
        "tiny spec (chunked prefill, chunk 32): serve_ttft_ms = cold "
        "64-token prompt, serve_ttft_cold_us / serve_ttft_warm_us = a "
        "193-token prompt cold vs with its first 160 positions already "
        "cached (the prefix-cache splice), serve_itl_ms_per_tok = mean "
        "single-token decode at ~200 ctx; HTTP/scheduler overhead "
        "between socket write and first compute is excluded (about "
        "1/serve_keepalive_req_s). The prefix_warm token counts are "
        "exact arithmetic. Regenerate natively as above to replace "
        "this calibration.")
    with open(bench_path, "w", encoding="utf-8") as f:
        json.dump(report, f, indent=1)
        f.write("\n")
    print(f"updated {bench_path}:")
    for k in ("serve_ttft_ms", "serve_itl_ms_per_tok",
              "serve_ttft_cold_us", "serve_ttft_warm_us"):
        print(f"  {k:>22} = {tracked[k]}")


if __name__ == "__main__":
    main()
