#!/usr/bin/env python3
"""Gate CI on the perf trajectory: compare a fresh bench JSON report
against the committed baseline and fail on a large regression.

Usage:
    bench_check.py BASELINE.json FRESH.json [--threshold 0.30]

Both files are `switchlora-bench-v2` reports (written by the bench
binaries' `--json` flag; see `rust/src/bench/mod.rs`).  Only the flat
`tracked` table is compared, on the keys the two reports share.  The
naming convention carries the direction: keys ending `_gflops`,
`_tok_s` or `_req_s` are higher-is-better, `_ms` or `_ms_per_tok`
lower-is-better, as is `_us` (microsecond latencies).

A metric REGRESSES when it moves against its direction by more than
`--threshold` (default 0.30 = 30%, the ISSUE 6 gate) relative to the
baseline value.  Any regression -> exit 1.

Advisory (exit 0) cases, each printed loudly rather than silently
passed:
  * baseline file missing or has no/empty `tracked` table (a seed
    report predating the trajectory, or a first run on a new metric);
  * `host` fingerprints differ -- timings from different machines are
    not comparable, so the check degrades to a notice asking for a
    baseline refresh.

stdlib only; no third-party imports.
"""

import json
import os
import sys

HIGHER_BETTER = ("_gflops", "_tok_s", "_req_s")
LOWER_BETTER = ("_ms", "_ms_per_tok", "_us")


def direction(key):
    """+1 higher-is-better, -1 lower-is-better, 0 untracked suffix."""
    if key.endswith(HIGHER_BETTER):
        return 1
    if key.endswith(LOWER_BETTER):
        return -1
    return 0


def load(path):
    with open(path, "r", encoding="utf-8") as f:
        return json.load(f)


def main(argv):
    threshold = 0.30
    args = []
    i = 1
    while i < len(argv):
        a = argv[i]
        if a == "--threshold" and i + 1 < len(argv):
            i += 1
            threshold = float(argv[i])
        elif a.startswith("--threshold="):
            threshold = float(a.split("=", 1)[1])
        else:
            args.append(a)
        i += 1
    if len(args) != 2:
        print(__doc__)
        return 2
    baseline_path, fresh_path = args

    if not os.path.exists(baseline_path):
        print(f"bench_check: no baseline at {baseline_path} -- "
              "nothing to compare (commit a fresh report to start the "
              "trajectory)")
        return 0
    base = load(baseline_path)
    fresh = load(fresh_path)

    bt = base.get("tracked") or {}
    ft = fresh.get("tracked") or {}
    if not bt:
        print(f"bench_check: baseline {baseline_path} has no tracked "
              "table -- advisory pass (regenerate and commit it)")
        return 0
    if not ft:
        print(f"bench_check: FRESH report {fresh_path} has no tracked "
              "table -- the bench binary regressed its --json output")
        return 1

    bhost, fhost = base.get("host"), fresh.get("host")
    if bhost and fhost and bhost != fhost:
        print("bench_check: host fingerprint changed, timings not "
              "comparable -- advisory pass")
        print(f"  baseline host: {bhost}")
        print(f"  fresh host:    {fhost}")
        print("  refresh the committed baseline on this machine to "
              "re-arm the gate")
        return 0

    shared = sorted(set(bt) & set(ft))
    if not shared:
        print("bench_check: no shared tracked keys -- advisory pass")
        return 0

    failures = []
    print(f"bench_check: threshold {threshold:.0%}, "
          f"{len(shared)} shared metric(s)")
    for key in shared:
        d = direction(key)
        b, f = bt[key], ft[key]
        if d == 0 or not isinstance(b, (int, float)) \
                or not isinstance(f, (int, float)) or b <= 0 or f <= 0:
            print(f"  {key:<32} skipped (unrecognized suffix or "
                  "non-positive value)")
            continue
        # fraction moved against the metric's good direction
        regression = (b - f) / b if d > 0 else (f - b) / b
        arrow = "better" if regression <= 0 else "worse"
        status = "OK"
        if regression > threshold:
            status = "FAIL"
            failures.append(key)
        print(f"  {key:<32} {b:>12.3f} -> {f:>12.3f}  "
              f"({abs(regression):.1%} {arrow})  {status}")

    dropped = sorted(set(bt) - set(ft))
    if dropped:
        print(f"  note: baseline-only keys not in fresh report: "
              f"{', '.join(dropped)}")

    if failures:
        print(f"bench_check: FAIL -- {len(failures)} metric(s) "
              f"regressed >{threshold:.0%}: {', '.join(failures)}")
        return 1
    print("bench_check: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
