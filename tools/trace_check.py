#!/usr/bin/env python3
"""Validate a switchlora telemetry trace (JSONL or Chrome trace-event).

Usage:
    trace_check.py TRACE [--format jsonl|chrome]
                   [--require-phases] [--require-switch]

With `--format` omitted the format is sniffed: a file whose first
non-space byte is `[` is treated as a Chrome trace-event array,
anything else as JSONL (one event object per line).

JSONL schema (see `rust/src/obs/sink.rs`): every line is a JSON object
with `kind` (str), `ts` (number >= 0, microseconds) and `tid`
(integer >= 1).  Per-kind payloads are checked where the schema is
load-bearing:

  * span        -- name/cat strings, dur >= 0
  * comm.round  -- bytes/elems/workers numbers, wire string
  * switch      -- step/slot/pool_slot/len/freeze_until numbers,
                   layer/side strings
  * memory      -- context string, rows[] of {component,dtype,bytes},
                   and total == sum(rows.bytes) exactly
  * hist        -- edges strictly ascending, len(counts) == len(edges)+1,
                   count == sum(counts)
  * run_summary -- steps/comm_bytes/comm_rounds numbers; when
                   comm.round events are present their byte sum must
                   equal comm_bytes exactly (the ledger cross-check)

Chrome schema: a JSON array where every event has name/ph/ts/pid/tid,
and `ph == "X"` events also carry `dur` -- the minimum Perfetto and
chrome://tracing need to load the file.

`--require-phases` additionally fails unless all eight trainer phases
(data forward backward allreduce optim switch eval checkpoint) appear
as `cat == "phase"` spans; `--require-switch` fails unless at least one
switch audit event is present.  CI runs both against a traced smoke
train.

Exit 0 with a one-line summary when the trace is valid, exit 1 with
every violation listed otherwise.  stdlib only; no third-party imports.
"""

import json
import sys

PHASES = ("data", "forward", "backward", "allreduce", "optim", "switch",
          "eval", "checkpoint")


def is_num(x):
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def check_common(ev, where, errors):
    ts = ev.get("ts")
    if not is_num(ts) or ts < 0:
        errors.append(f"{where}: bad ts {ts!r}")
    tid = ev.get("tid")
    if not is_num(tid) or tid < 1 or int(tid) != tid:
        errors.append(f"{where}: bad tid {tid!r}")


def check_jsonl_event(ev, where, errors, seen):
    kind = ev.get("kind")
    if not isinstance(kind, str) or not kind:
        errors.append(f"{where}: missing kind")
        return
    check_common(ev, where, errors)
    if kind == "span":
        for key in ("name", "cat"):
            if not isinstance(ev.get(key), str):
                errors.append(f"{where}: span missing {key}")
        dur = ev.get("dur")
        if not is_num(dur) or dur < 0:
            errors.append(f"{where}: span bad dur {dur!r}")
        if ev.get("cat") == "phase":
            seen["phases"].add(ev.get("name"))
    elif kind == "comm.round":
        for key in ("bytes", "elems", "workers"):
            if not is_num(ev.get(key)):
                errors.append(f"{where}: comm.round bad {key}")
        if not isinstance(ev.get("wire"), str):
            errors.append(f"{where}: comm.round missing wire")
        if is_num(ev.get("bytes")):
            seen["comm_bytes"] += ev["bytes"]
            seen["comm_rounds"] += 1
    elif kind == "switch":
        for key in ("step", "slot", "pool_slot", "len", "freeze_until"):
            if not is_num(ev.get(key)):
                errors.append(f"{where}: switch bad {key}")
        for key in ("layer", "side"):
            if not isinstance(ev.get(key), str):
                errors.append(f"{where}: switch missing {key}")
        if ev.get("side") not in ("a", "b"):
            errors.append(f"{where}: switch side {ev.get('side')!r}")
        seen["switches"] += 1
    elif kind == "memory":
        if not isinstance(ev.get("context"), str):
            errors.append(f"{where}: memory missing context")
        rows = ev.get("rows")
        if not isinstance(rows, list) or not rows:
            errors.append(f"{where}: memory rows missing/empty")
            return
        total = 0
        for i, row in enumerate(rows):
            if not isinstance(row.get("component"), str) \
                    or not isinstance(row.get("dtype"), str) \
                    or not is_num(row.get("bytes")):
                errors.append(f"{where}: memory row {i} malformed")
                return
            total += row["bytes"]
        if total != ev.get("total"):
            errors.append(f"{where}: memory total {ev.get('total')!r} "
                          f"!= sum of rows {total}")
    elif kind == "hist":
        edges, counts = ev.get("edges"), ev.get("counts")
        if not isinstance(edges, list) or not isinstance(counts, list):
            errors.append(f"{where}: hist missing edges/counts")
            return
        if any(b <= a for a, b in zip(edges, edges[1:])):
            errors.append(f"{where}: hist edges not ascending")
        if len(counts) != len(edges) + 1:
            errors.append(f"{where}: hist has {len(counts)} counts for "
                          f"{len(edges)} edges (want edges+1)")
        if sum(counts) != ev.get("count"):
            errors.append(f"{where}: hist count {ev.get('count')!r} != "
                          f"sum(counts) {sum(counts)}")
    elif kind == "run_summary":
        for key in ("steps", "comm_bytes", "comm_rounds"):
            if not is_num(ev.get(key)):
                errors.append(f"{where}: run_summary bad {key}")
        seen["summary"] = ev
    # other kinds (kv, counters, gauges, custom) only need the common
    # fields -- forward compatible by design


def check_jsonl(text, path, errors, seen):
    n = 0
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        where = f"{path}:{ln}"
        try:
            ev = json.loads(line)
        except ValueError as e:
            errors.append(f"{where}: not JSON ({e})")
            continue
        if not isinstance(ev, dict):
            errors.append(f"{where}: event is not an object")
            continue
        n += 1
        check_jsonl_event(ev, where, errors, seen)
    if n == 0:
        errors.append(f"{path}: empty trace")
    summary = seen.get("summary")
    if summary is not None and seen["comm_rounds"] > 0:
        if seen["comm_bytes"] != summary.get("comm_bytes"):
            errors.append(
                f"{path}: comm.round events sum to {seen['comm_bytes']} "
                f"bytes but run_summary claims "
                f"{summary.get('comm_bytes')}")
        if seen["comm_rounds"] != summary.get("comm_rounds"):
            errors.append(
                f"{path}: {seen['comm_rounds']} comm.round events but "
                f"run_summary claims {summary.get('comm_rounds')}")
    return n


def check_chrome(text, path, errors, seen):
    try:
        arr = json.loads(text)
    except ValueError as e:
        errors.append(f"{path}: not JSON ({e})")
        return 0
    if not isinstance(arr, list):
        errors.append(f"{path}: chrome trace must be a JSON array")
        return 0
    if not arr:
        errors.append(f"{path}: empty trace")
    for i, ev in enumerate(arr):
        where = f"{path}[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: event is not an object")
            continue
        for key in ("name", "ph"):
            if not isinstance(ev.get(key), str):
                errors.append(f"{where}: missing {key}")
        for key in ("ts", "pid", "tid"):
            if not is_num(ev.get(key)):
                errors.append(f"{where}: bad {key}")
        if ev.get("ph") == "X":
            if not is_num(ev.get("dur")):
                errors.append(f"{where}: duration event without dur")
            if ev.get("cat") == "phase":
                seen["phases"].add(ev.get("name"))
        if ev.get("ph") == "i" and ev.get("name") == "switch":
            seen["switches"] += 1
    return len(arr)


def main(argv):
    path = None
    fmt = None
    require_phases = False
    require_switch = False
    i = 0
    while i < len(argv):
        a = argv[i]
        if a == "--format":
            fmt = argv[i + 1]
            i += 2
        elif a == "--require-phases":
            require_phases = True
            i += 1
        elif a == "--require-switch":
            require_switch = True
            i += 1
        elif path is None:
            path = a
            i += 1
        else:
            print(f"unexpected argument {a!r}", file=sys.stderr)
            return 2
    if path is None:
        print(__doc__, file=sys.stderr)
        return 2

    try:
        with open(path, encoding="utf-8") as f:
            text = f.read()
    except OSError as e:
        print(f"cannot read {path}: {e}", file=sys.stderr)
        return 1
    if fmt is None:
        fmt = "chrome" if text.lstrip()[:1] == "[" else "jsonl"

    errors = []
    seen = {"phases": set(), "switches": 0, "comm_bytes": 0,
            "comm_rounds": 0, "summary": None}
    if fmt == "jsonl":
        n = check_jsonl(text, path, errors, seen)
    elif fmt == "chrome":
        n = check_chrome(text, path, errors, seen)
    else:
        print(f"unknown --format {fmt!r}", file=sys.stderr)
        return 2

    if require_phases:
        missing = [p for p in PHASES if p not in seen["phases"]]
        if missing:
            errors.append(f"{path}: phase coverage incomplete, missing "
                          + " ".join(missing))
    if require_switch and seen["switches"] == 0:
        errors.append(f"{path}: no switch audit events")

    if errors:
        for e in errors[:50]:
            print(f"SCHEMA: {e}")
        if len(errors) > 50:
            print(f"... and {len(errors) - 50} more")
        print(f"FAIL: {len(errors)} violation(s) in {path}")
        return 1
    print(f"OK: {path} [{fmt}] {n} events, "
          f"{len(seen['phases'])} phase(s), {seen['switches']} "
          f"switch event(s), {seen['comm_rounds']} comm round(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
