#!/usr/bin/env python3
"""Seed the committed BENCH_serve.json trajectory file with honest
timings when no Rust toolchain is available.

The canonical way to (re)generate the report is
`cargo bench --bench bench_serve -- --json BENCH_serve.json`.  This
script exists for environments that can compile C but not Rust: it
emits a C transliteration of the server's connection fast-path -- a
threaded accept loop serving a fixed healthz-sized JSON body with ONE
write() per response and TCP_NODELAY, a keep-alive handler loop bounded
at 128 requests/connection, and a client driving it first over one
persistent socket and then with a fresh connect + `Connection: close`
per request -- compiles it with `gcc -O3`, runs it against 127.0.0.1,
and records the two req/s figures.  The syscall pattern per request
(read head, one write, optional connect/close pair) matches
`serve/server.rs`; what the transliteration cannot reproduce is the
Rust model behind `/v1/generate`, so the `serve_ttft_ms` /
`serve_itl_ms_per_tok` fields are OMITTED rather than committed as
made-up numbers.

The `kv_residency` table is exact arithmetic, not timing: pool bytes =
blocks x block_bytes with the same formulas `infer/kv_cache.rs` uses at
the tiny-spec geometry bench_serve.rs benches (layers 2, heads 4,
head_dim 16, batch 8, capacity 256, block 32, f32).  stdlib only.
"""

import json
import os
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

C_SRC = r"""
#include <arpa/inet.h>
#include <netinet/in.h>
#include <netinet/tcp.h>
#include <pthread.h>
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <strings.h>
#include <time.h>
#include <unistd.h>

static double now_s(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec + ts.tv_nsec / 1e9;
}

/* a healthz-sized JSON body, close to what serve/server.rs emits */
static const char *BODY =
    "{\"ok\":true,\"draining\":false,\"active\":0,\"queued\":0,"
    "\"queued_by_tenant\":{},\"received\":0,\"completed\":0,"
    "\"rejected\":0,\"tokens_streamed\":0,\"adapters\":[]}";

#define MAX_REQUESTS_PER_CONN 128

/* read until the blank line; returns head length or 0 on EOF */
static int read_head(int fd, char *buf, int cap) {
    int n = 0;
    while (n < cap - 1) {
        int r = (int)read(fd, buf + n, 1);
        if (r <= 0) return 0;
        n += r;
        if (n >= 4 && !memcmp(buf + n - 4, "\r\n\r\n", 4)) break;
    }
    buf[n] = 0;
    return n;
}

static void *conn_thread(void *arg) {
    int fd = (int)(long)arg;
    int one = 1;
    setsockopt(fd, IPPROTO_TCP, TCP_NODELAY, &one, sizeof one);
    char head[4096], resp[1024];
    for (int served = 0; served < MAX_REQUESTS_PER_CONN; served++) {
        if (!read_head(fd, head, sizeof head)) break;
        /* per-request Connection handling, like Request::wants_keep_alive */
        int keep = served + 1 < MAX_REQUESTS_PER_CONN;
        char *c = head;
        while ((c = strcasestr(c, "connection:")) != NULL) {
            c += 11;
            if (strncasecmp(c + strspn(c, " "), "close", 5) == 0) keep = 0;
            break;
        }
        /* ONE write per response, like http::respond */
        int m = snprintf(resp, sizeof resp,
                         "HTTP/1.1 200 OK\r\nContent-Type: application/json\r\n"
                         "Content-Length: %zu\r\nConnection: %s\r\n\r\n%s",
                         strlen(BODY), keep ? "keep-alive" : "close", BODY);
        if (write(fd, resp, m) != m) break;
        if (!keep) break;
    }
    close(fd);
    return NULL;
}

static void *accept_thread(void *arg) {
    int lfd = (int)(long)arg;
    for (;;) {
        int fd = accept(lfd, NULL, NULL);
        if (fd < 0) break;
        pthread_t t;
        pthread_create(&t, NULL, conn_thread, (void *)(long)fd);
        pthread_detach(t);
    }
    return NULL;
}

static int connect_srv(int port) {
    int fd = socket(AF_INET, SOCK_STREAM, 0);
    int one = 1;
    setsockopt(fd, IPPROTO_TCP, TCP_NODELAY, &one, sizeof one);
    struct sockaddr_in a = {0};
    a.sin_family = AF_INET;
    a.sin_port = htons(port);
    a.sin_addr.s_addr = htonl(INADDR_LOOPBACK);
    if (connect(fd, (struct sockaddr *)&a, sizeof a) < 0) {
        perror("connect");
        exit(1);
    }
    return fd;
}

/* read one response: head, then Content-Length body bytes */
static void read_response(int fd) {
    char head[4096];
    int n = read_head(fd, head, sizeof head);
    if (!n) { fprintf(stderr, "EOF in head\n"); exit(1); }
    char *cl = strcasestr(head, "content-length:");
    int want = cl ? atoi(cl + 15) : 0;
    char body[4096];
    while (want > 0) {
        int r = (int)read(fd, body, want < (int)sizeof body ? want : (int)sizeof body);
        if (r <= 0) { fprintf(stderr, "EOF in body\n"); exit(1); }
        want -= r;
    }
}

int main(void) {
    int lfd = socket(AF_INET, SOCK_STREAM, 0);
    int one = 1;
    setsockopt(lfd, SOL_SOCKET, SO_REUSEADDR, &one, sizeof one);
    struct sockaddr_in a = {0};
    a.sin_family = AF_INET;
    a.sin_addr.s_addr = htonl(INADDR_LOOPBACK);
    a.sin_port = 0;
    if (bind(lfd, (struct sockaddr *)&a, sizeof a) < 0 ||
        listen(lfd, 64) < 0) {
        perror("bind/listen");
        return 1;
    }
    socklen_t alen = sizeof a;
    getsockname(lfd, (struct sockaddr *)&a, &alen);
    int port = ntohs(a.sin_port);
    pthread_t srv;
    pthread_create(&srv, NULL, accept_thread, (void *)(long)lfd);

    const char *ka_req = "GET /healthz HTTP/1.1\r\nHost: b\r\n\r\n";
    const char *cl_req =
        "GET /healthz HTTP/1.1\r\nHost: b\r\nConnection: close\r\n\r\n";
    const int N = 3000, WARM = 100;

    /* warm both paths */
    int fd = connect_srv(port);
    for (int i = 0; i < WARM; i++) {
        if (i && i % (MAX_REQUESTS_PER_CONN - 1) == 0) {
            close(fd);
            fd = connect_srv(port);
        }
        write(fd, ka_req, strlen(ka_req));
        read_response(fd);
    }
    close(fd);
    for (int i = 0; i < WARM; i++) {
        int c = connect_srv(port);
        write(c, cl_req, strlen(cl_req));
        read_response(c);
        close(c);
    }

    /* keep-alive: one socket, reconnecting only at the 128-req bound */
    double t0 = now_s();
    fd = connect_srv(port);
    for (int i = 0; i < N; i++) {
        if (i && i % (MAX_REQUESTS_PER_CONN - 1) == 0) {
            close(fd);
            fd = connect_srv(port);
        }
        write(fd, ka_req, strlen(ka_req));
        read_response(fd);
    }
    close(fd);
    double ka = N / (now_s() - t0);

    /* close-per-request: fresh connect + teardown every time */
    t0 = now_s();
    for (int i = 0; i < N; i++) {
        int c = connect_srv(port);
        write(c, cl_req, strlen(cl_req));
        read_response(c);
        close(c);
    }
    double cl = N / (now_s() - t0);

    printf("{\"keepalive_req_s\": %.1f, \"close_req_s\": %.1f}\n", ka, cl);
    return 0;
}
"""


def host_fingerprint():
    try:
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.startswith("model name"):
                    return line.split(":", 1)[1].strip()
    except OSError:
        pass
    return "unknown"


def measure_req_s():
    with tempfile.TemporaryDirectory() as td:
        src = os.path.join(td, "serve_path.c")
        exe = os.path.join(td, "serve_path")
        with open(src, "w") as f:
            f.write(C_SRC)
        subprocess.run(
            ["gcc", "-O3", "-D_GNU_SOURCE", "-o", exe, src, "-lpthread"],
            check=True)
        out = subprocess.run([exe], check=True, capture_output=True,
                             text=True).stdout
    return json.loads(out)


def kv_residency_rows():
    # tiny spec geometry, exactly as bench_serve.rs benches it
    layers, heads, head_dim = 2, 4, 16
    batch, capacity, block = 8, 256, 32
    f32 = 4
    per_buf_block = block * heads * head_dim * f32
    block_bytes = 2 * layers * per_buf_block          # K+V, every layer
    slab_bytes = 2 * layers * batch * capacity * heads * head_dim * f32
    rows = []
    live_slots = batch // 2   # half the slots live, like the bench
    for live_per_seq in (0, 16, 64, 128):
        blocks = live_slots * -(-live_per_seq // block) \
            if live_per_seq else 0
        rows.append({
            "live_tokens": live_per_seq * live_slots,
            "pool_bytes": blocks * block_bytes,
            "slab_bytes": slab_bytes,
        })
    return rows


def main():
    req = measure_req_s()
    ka, cl = req["keepalive_req_s"], req["close_req_s"]
    report = {
        "schema": "switchlora-bench-v2",
        "bench": "bench_serve",
        "host": host_fingerprint(),
        "threads": 1,
        "note": ("seed report: the req/s figures are measured by "
                 "tools/seed_bench_serve.py -- a C transliteration of "
                 "the server's connection fast-path (threaded accept "
                 "loop, per-request Connection handling, one write() "
                 "per response, TCP_NODELAY, 128-requests/connection "
                 "bound) compiled with gcc -O3 and driven over real "
                 "loopback sockets on the host named above; the "
                 "kv_residency table is exact arithmetic from the "
                 "formulas infer/kv_cache.rs uses (tiny spec, batch 8, "
                 "capacity 256, block 32, f32). serve_ttft_ms and "
                 "serve_itl_ms_per_tok are omitted because the "
                 "transliteration does not run the Rust model. "
                 "Regenerate natively with `cargo bench --bench "
                 "bench_serve -- --json BENCH_serve.json` and commit "
                 "the result to replace this calibration."),
        "results": [],
        "tracked": {
            "serve_keepalive_req_s": round(ka, 1),
            "serve_close_req_s": round(cl, 1),
        },
        "kv_residency": kv_residency_rows(),
    }
    out = os.path.join(REPO, "BENCH_serve.json")
    with open(out, "w") as f:
        json.dump(report, f, indent=1)
        f.write("\n")
    print(f"keep-alive {ka:.0f} req/s  close-per-request {cl:.0f} req/s "
          f"({ka / max(cl, 1e-9):.2f}x)")
    print(f"wrote {out}")
    if ka <= cl:
        print("WARNING: keep-alive did not beat close-per-request on "
              "this host", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
