#!/usr/bin/env python3
"""Seed the committed BENCH_*.json trajectory files with honest timings
when no Rust toolchain is available.

The repo's bench binaries (`cargo bench --bench bench_micro/bench_infer
-- --json <path>`) are the canonical way to (re)generate the committed
reports.  This script exists for environments that can compile C but
not Rust: it emits a C transliteration of the restructured kernels --
the same lane-split `dot` (8 independent accumulators, fixed pairwise
reduction), the o-outer panel-dequant packed matmul, the int8-native
integer-dot path, cached attention, and a per-token decode workload at
the tiny/s1m model shapes -- compiles it with `gcc -O3 -march=native`,
runs it single-threaded, and writes both BENCH files with:

  * `tracked` tables measured from the transliteration (the fields
    `tools/bench_check.py` gates on), with a provenance note saying
    exactly where the numbers came from;
  * byte tables carried over from the existing committed reports (they
    are exact -- computed from the same formulas the binaries use);
  * `threads: 1` (the true thread count of the measurement) and the
    real host fingerprint.

Fields the transliteration cannot measure honestly (e.g. the
`max_logit_*` deviation columns, which need the full model) are OMITTED
rather than committed as null.  stdlib only.
"""

import json
import os
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

C_SRC = r"""
#include <stdio.h>
#include <stdlib.h>
#include <string.h>
#include <stdint.h>
#include <math.h>
#include <time.h>

static double now_ms(void) {
    struct timespec ts;
    clock_gettime(CLOCK_MONOTONIC, &ts);
    return ts.tv_sec * 1e3 + ts.tv_nsec / 1e6;
}

static uint64_t rng_state = 0x9E3779B97F4A7C15ull;
static float frand(void) {
    rng_state ^= rng_state << 13;
    rng_state ^= rng_state >> 7;
    rng_state ^= rng_state << 17;
    return (float)((rng_state >> 11) & 0xFFFFFF) / (float)0x1000000 - 0.5f;
}
static float *fvec(size_t n) {
    float *p = malloc(n * sizeof(float));
    for (size_t i = 0; i < n; i++) p[i] = 0.2f * frand();
    return p;
}

volatile float sink;

/* --- the restructured kernel inner loops (mirrors kernels/mod.rs) --- */

#define LANES 8

static float dotf(const float *a, const float *b, int k) {
    float lanes[LANES] = {0};
    int kk = k - k % LANES;
    float tail = 0.0f;
    for (int j = kk; j < k; j++) tail += a[j] * b[j];
    for (int j = 0; j < kk; j += LANES)
        for (int l = 0; l < LANES; l++) lanes[l] += a[j + l] * b[j + l];
    return ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
         + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7])) + tail;
}

static int32_t doti8(const int8_t *a, const int8_t *b, int k) {
    int32_t lanes[LANES] = {0};
    int kk = k - k % LANES;
    int32_t tail = 0;
    for (int j = kk; j < k; j++) tail += (int32_t)a[j] * b[j];
    for (int j = 0; j < kk; j += LANES)
        for (int l = 0; l < LANES; l++)
            lanes[l] += (int32_t)a[j + l] * b[j + l];
    int32_t s = tail;
    for (int l = 0; l < LANES; l++) s += lanes[l];
    return s;
}

static void axpy(float *y, float s, const float *x, int n) {
    for (int i = 0; i < n; i++) y[i] += s * x[i];
}

static float quant_row(const float *row, int8_t *out, int k) {
    float amax = 0.0f;
    for (int j = 0; j < k; j++) {
        float a = fabsf(row[j]);
        if (a > amax) amax = a;
    }
    if (amax == 0.0f) { memset(out, 0, k); return 0.0f; }
    float inv = 127.0f / amax;
    for (int j = 0; j < k; j++) {
        float v = roundf(row[j] * inv);
        out[j] = (int8_t)(v > 127.0f ? 127 : (v < -127.0f ? -127 : v));
    }
    return amax / 127.0f;
}

/* one [m x k] weight packed in every dtype */
typedef struct { float *f; uint16_t *h; int8_t *q; float *sc; } W;

static W packw(const float *w, int m, int k) {
    W o;
    size_t n = (size_t)m * k;
    o.f = malloc(n * 4); memcpy(o.f, w, n * 4);
    o.h = malloc(n * 2);
    for (size_t i = 0; i < n; i++) {        /* bf16 round-nearest-even */
        uint32_t b; memcpy(&b, &w[i], 4);
        o.h[i] = (uint16_t)((b + 0x7FFF + ((b >> 16) & 1)) >> 16);
    }
    o.q = malloc(n); o.sc = malloc((size_t)m * 4);
    for (int r = 0; r < m; r++)
        o.sc[r] = quant_row(w + (size_t)r * k, o.q + (size_t)r * k, k);
    return o;
}

/* y[1 x m] += x[1 x k] . W^T, RHS dispatched by dtype
   (0 = f32, 1 = bf16 panel-dequant, 2 = i8 panel-dequant) */
static void lin1(float *y, const float *x, const W *w, int k, int m,
                 int dt, float *panel) {
    if (dt == 0) {
        for (int o = 0; o < m; o++) y[o] += dotf(x, w->f + (size_t)o * k, k);
    } else if (dt == 1) {
        for (int o = 0; o < m; o++) {
            for (int j = 0; j < k; j++) {
                uint32_t b = ((uint32_t)w->h[(size_t)o * k + j]) << 16;
                float f; memcpy(&f, &b, 4);
                panel[j] = f;
            }
            y[o] += dotf(x, panel, k);
        }
    } else {
        for (int o = 0; o < m; o++) {
            float s = w->sc[o];
            const int8_t *qr = w->q + (size_t)o * k;
            for (int j = 0; j < k; j++) panel[j] = s * qr[j];
            y[o] += dotf(x, panel, k);
        }
    }
}

/* --- tracked kernel workloads ---------------------------------------- */

static double matmul_ms(int dt, int native, int rows, int k, int m,
                        int warm, int iters) {
    float *x = fvec((size_t)rows * k), *wr = fvec((size_t)m * k);
    float *y = malloc((size_t)rows * m * 4);
    float *panel = malloc((size_t)k * 4);
    int8_t *qx = malloc((size_t)k);
    W w = packw(wr, m, k);
    double t0 = 0;
    for (int it = 0; it < warm + iters; it++) {
        if (it == warm) t0 = now_ms();
        memset(y, 0, (size_t)rows * m * 4);
        if (native) {
            for (int i = 0; i < rows; i++) {
                float sx = quant_row(x + (size_t)i * k, qx, k);
                if (sx == 0.0f) continue;
                for (int o = 0; o < m; o++)
                    y[(size_t)i * m + o] += (sx * w.sc[o])
                        * (float)doti8(qx, w.q + (size_t)o * k, k);
            }
        } else if (dt == 0) {
            for (int i = 0; i < rows; i++)
                for (int o = 0; o < m; o++)
                    y[(size_t)i * m + o] +=
                        dotf(x + (size_t)i * k, w.f + (size_t)o * k, k);
        } else {
            /* o-outer panel dequant, as addmm_nt_packed */
            for (int o = 0; o < m; o++) {
                float s = w.sc[o];
                const int8_t *qr = w.q + (size_t)o * k;
                for (int j = 0; j < k; j++) panel[j] = s * qr[j];
                for (int i = 0; i < rows; i++)
                    y[(size_t)i * m + o] += dotf(x + (size_t)i * k, panel, k);
            }
        }
        sink += y[0];
    }
    return (now_ms() - t0) / iters;
}

static double attention_ms(int bh, int t, int hd, int warm, int iters) {
    float *q = fvec((size_t)bh * t * hd), *k = fvec((size_t)bh * t * hd);
    float *v = fvec((size_t)bh * t * hd);
    float *o = malloc((size_t)bh * t * hd * 4);
    float *att = malloc((size_t)t * 4);
    float scale = 1.0f / sqrtf((float)hd);
    double t0 = 0;
    for (int it = 0; it < warm + iters; it++) {
        if (it == warm) t0 = now_ms();
        memset(o, 0, (size_t)bh * t * hd * 4);
        for (int g = 0; g < bh; g++) {
            const float *qg = q + (size_t)g * t * hd;
            const float *kg = k + (size_t)g * t * hd;
            const float *vg = v + (size_t)g * t * hd;
            float *og = o + (size_t)g * t * hd;
            for (int i = 0; i < t; i++) {
                float mx = -1e30f;
                for (int j = 0; j <= i; j++) {
                    float z = dotf(qg + (size_t)i * hd,
                                   kg + (size_t)j * hd, hd) * scale;
                    att[j] = z;
                    if (z > mx) mx = z;
                }
                float den = 0.0f;
                for (int j = 0; j <= i; j++) {
                    float e = expf(att[j] - mx);
                    att[j] = e;
                    den += e;
                }
                for (int j = 0; j <= i; j++)
                    axpy(og + (size_t)i * hd, att[j] / den,
                         vg + (size_t)j * hd, hd);
            }
        }
        sink += o[0];
    }
    return (now_ms() - t0) / iters;
}

/* --- per-token decode workload at a model shape ---------------------- */

typedef struct { int h, L, nh, hd, ff, vocab, r; } Dims;

static double decode_ms(Dims d, int dt, int use_lora, int ctx,
                        int warm, int iters) {
    int h = d.h, ff = d.ff, r = d.r, L = d.L, nh = d.nh, hd = d.hd;
    int vocab = d.vocab;
    int mx_dim = ff > h ? ff : h;
    W *wl = malloc(sizeof(W) * (size_t)L * 6);  /* q k v o up down */
    float **la = NULL, **lb = NULL;
    int ins[6], outs[6];
    ins[0] = ins[1] = ins[2] = ins[3] = h; ins[4] = h; ins[5] = ff;
    outs[0] = outs[1] = outs[2] = outs[3] = h; outs[4] = ff; outs[5] = h;
    for (int l = 0; l < L; l++)
        for (int s = 0; s < 6; s++) {
            float *raw = fvec((size_t)outs[s] * ins[s]);
            wl[l * 6 + s] = packw(raw, outs[s], ins[s]);
            free(raw);
        }
    if (use_lora) {
        la = malloc(sizeof(float *) * (size_t)L * 6);
        lb = malloc(sizeof(float *) * (size_t)L * 6);
        for (int l = 0; l < L; l++)
            for (int s = 0; s < 6; s++) {
                la[l * 6 + s] = fvec((size_t)r * ins[s]);
                lb[l * 6 + s] = fvec((size_t)outs[s] * r);
            }
    }
    float *head_raw = fvec((size_t)vocab * h);
    W head = packw(head_raw, vocab, h);
    free(head_raw);
    float *kc = fvec((size_t)L * nh * ctx * hd);
    float *vc = fvec((size_t)L * nh * ctx * hd);
    float *panel = malloc((size_t)mx_dim * 4);
    float *x = fvec(h);
    float *qb = malloc((size_t)h * 4), *kb = malloc((size_t)h * 4);
    float *vb = malloc((size_t)h * 4), *ob = malloc((size_t)h * 4);
    float *an = malloc((size_t)h * 4), *u = malloc((size_t)ff * 4);
    float *t2 = malloc((size_t)h * 4), *t1 = malloc((size_t)r * 4);
    float *scores = malloc((size_t)ctx * 4);
    float *logits = malloc((size_t)vocab * 4);
    float scale = 1.0f / sqrtf((float)hd), ls = 0.5f;
    double t0 = 0;
    for (int it = 0; it < warm + iters; it++) {
        if (it == warm) t0 = now_ms();
        for (int l = 0; l < L; l++) {
            float *proj[4] = {qb, kb, vb, ob};
            for (int s = 0; s < 3; s++) {
                memset(proj[s], 0, (size_t)h * 4);
                lin1(proj[s], x, &wl[l * 6 + s], h, h, dt, panel);
                if (use_lora) {
                    memset(t1, 0, (size_t)r * 4);
                    for (int o = 0; o < r; o++)
                        t1[o] += dotf(x, la[l * 6 + s] + (size_t)o * h, h);
                    for (int o = 0; o < h; o++)
                        proj[s][o] += ls
                            * dotf(t1, lb[l * 6 + s] + (size_t)o * r, r);
                }
            }
            /* append k/v at a rotating cache slot, then attend over ctx */
            int slot = it % ctx;
            for (int g = 0; g < nh; g++) {
                memcpy(kc + (((size_t)l * nh + g) * ctx + slot) * hd,
                       kb + (size_t)g * hd, (size_t)hd * 4);
                memcpy(vc + (((size_t)l * nh + g) * ctx + slot) * hd,
                       vb + (size_t)g * hd, (size_t)hd * 4);
            }
            memset(an, 0, (size_t)h * 4);
            for (int g = 0; g < nh; g++) {
                const float *kg = kc + ((size_t)l * nh + g) * ctx * hd;
                const float *vg = vc + ((size_t)l * nh + g) * ctx * hd;
                float mxs = -1e30f;
                for (int j = 0; j < ctx; j++) {
                    float z = dotf(qb + (size_t)g * hd,
                                   kg + (size_t)j * hd, hd) * scale;
                    scores[j] = z;
                    if (z > mxs) mxs = z;
                }
                float den = 0.0f;
                for (int j = 0; j < ctx; j++) {
                    float e = expf(scores[j] - mxs);
                    scores[j] = e;
                    den += e;
                }
                for (int j = 0; j < ctx; j++)
                    axpy(an + (size_t)g * hd, scores[j] / den,
                         vg + (size_t)j * hd, hd);
            }
            memset(ob, 0, (size_t)h * 4);
            lin1(ob, an, &wl[l * 6 + 3], h, h, dt, panel);
            if (use_lora) {
                memset(t1, 0, (size_t)r * 4);
                for (int o = 0; o < r; o++)
                    t1[o] += dotf(an, la[l * 6 + 3] + (size_t)o * h, h);
                for (int o = 0; o < h; o++)
                    ob[o] += ls * dotf(t1, lb[l * 6 + 3] + (size_t)o * r, r);
            }
            for (int i = 0; i < h; i++) x[i] += 0.01f * ob[i];
            memset(u, 0, (size_t)ff * 4);
            lin1(u, x, &wl[l * 6 + 4], h, ff, dt, panel);
            if (use_lora) {
                memset(t1, 0, (size_t)r * 4);
                for (int o = 0; o < r; o++)
                    t1[o] += dotf(x, la[l * 6 + 4] + (size_t)o * h, h);
                for (int o = 0; o < ff; o++)
                    u[o] += ls * dotf(t1, lb[l * 6 + 4] + (size_t)o * r, r);
            }
            for (int i = 0; i < ff; i++)
                if (u[i] < 0.0f) u[i] = 0.0f;
            memset(t2, 0, (size_t)h * 4);
            lin1(t2, u, &wl[l * 6 + 5], ff, h, dt, panel);
            if (use_lora) {
                memset(t1, 0, (size_t)r * 4);
                for (int o = 0; o < r; o++)
                    t1[o] += dotf(u, la[l * 6 + 5] + (size_t)o * ff, ff);
                for (int o = 0; o < h; o++)
                    t2[o] += ls * dotf(t1, lb[l * 6 + 5] + (size_t)o * r, r);
            }
            for (int i = 0; i < h; i++) x[i] += 0.01f * t2[i];
        }
        memset(logits, 0, (size_t)vocab * 4);
        lin1(logits, x, &head, h, vocab, 0, panel);  /* head stays f32 */
        sink += logits[0];
    }
    return (now_ms() - t0) / iters;
}

int main(void) {
    /* tracked kernel shapes match the bench binaries exactly */
    printf("matmul_f32_ms %.6f\n", matmul_ms(0, 0, 1024, 512, 512, 2, 8));
    printf("matmul_i8_dequant_ms %.6f\n",
           matmul_ms(2, 0, 1024, 512, 512, 2, 8));
    printf("matmul_i8_native_ms %.6f\n",
           matmul_ms(0, 1, 1024, 512, 512, 2, 8));
    printf("attention_fwd_ms %.6f\n", attention_ms(16, 256, 32, 2, 8));
    /* model shapes, field order {h, L, nh, hd, ff, vocab, r} */
    Dims tiny = {64, 2, 4, 16, 128, 256, 16};
    Dims s1m = {128, 4, 4, 32, 256, 512, 32};
    /* tracked decode: LoRA variant, f32, ctx ~128+new (matches the
       largest row of the cached-decode table) */
    printf("decode_tiny_tracked_ms %.6f\n",
           decode_ms(tiny, 0, 1, 132, 200, 2000));
    printf("decode_s1m_tracked_ms %.6f\n",
           decode_ms(s1m, 0, 1, 132, 100, 1000));
    /* quantized-base table: merged dense variant, ctx ~64+new */
    printf("decode_tiny_f32_q_ms %.6f\n",
           decode_ms(tiny, 0, 0, 72, 200, 2000));
    printf("decode_tiny_bf16_q_ms %.6f\n",
           decode_ms(tiny, 1, 0, 72, 200, 2000));
    printf("decode_tiny_i8_q_ms %.6f\n",
           decode_ms(tiny, 2, 0, 72, 200, 2000));
    printf("decode_s1m_f32_q_ms %.6f\n",
           decode_ms(s1m, 0, 0, 72, 100, 1000));
    printf("decode_s1m_bf16_q_ms %.6f\n",
           decode_ms(s1m, 1, 0, 72, 100, 1000));
    printf("decode_s1m_i8_q_ms %.6f\n",
           decode_ms(s1m, 2, 0, 72, 100, 1000));
    fprintf(stderr, "sink %f\n", sink);
    return 0;
}
"""


def host_fingerprint():
    """Mirror of switchlora::bench::host_fingerprint()."""
    try:
        with open("/proc/cpuinfo", "r", encoding="utf-8") as f:
            for line in f:
                if line.startswith("model name"):
                    return line.split(":", 1)[1].strip()
    except OSError:
        pass
    import platform
    return f"{platform.machine()}-{sys.platform}"


def run_calibration():
    with tempfile.TemporaryDirectory() as td:
        src = os.path.join(td, "seed_bench.c")
        exe = os.path.join(td, "seed_bench")
        with open(src, "w", encoding="utf-8") as f:
            f.write(C_SRC)
        subprocess.run(
            ["gcc", "-O3", "-march=native", "-o", exe, src, "-lm"],
            check=True)
        out = subprocess.run([exe], check=True, capture_output=True,
                             text=True).stdout
    vals = {}
    for line in out.splitlines():
        parts = line.split()
        if len(parts) == 2:
            vals[parts[0]] = float(parts[1])
    return vals


NOTE = (
    "seed report: tracked timings measured by tools/seed_bench.py -- a C "
    "transliteration of the restructured kernels (same lane-split dot, "
    "o-outer panel dequant, and int8-native integer-dot inner loops) "
    "compiled with gcc -O3 -march=native and run single-threaded on the "
    "host named above; byte tables are exact (computed from the same "
    "formulas the bench binaries use). max_logit_* deviation fields are "
    "omitted because the transliteration does not run the full model. "
    "Regenerate natively with `cargo bench --bench bench_micro -- --json "
    "BENCH_kernels.json` / `--bench bench_infer -- --json "
    "BENCH_infer.json` and commit the result to replace this calibration."
)


def main():
    vals = run_calibration()
    host = host_fingerprint()
    flops = 2.0 * 1024 * 512 * 512

    def gflops(ms):
        return flops / (ms / 1e3) / 1e9

    kernels_path = os.path.join(REPO, "BENCH_kernels.json")
    infer_path = os.path.join(REPO, "BENCH_infer.json")
    with open(kernels_path, "r", encoding="utf-8") as f:
        old_kernels = json.load(f)
    with open(infer_path, "r", encoding="utf-8") as f:
        old_infer = json.load(f)

    kernels = {
        "schema": "switchlora-bench-v2",
        "bench": "bench_micro",
        "host": host,
        "threads": 1,
        "note": NOTE,
        "results": [],
        "tracked": {
            "matmul_f32_gflops": round(gflops(vals["matmul_f32_ms"]), 3),
            "matmul_i8_dequant_gflops":
                round(gflops(vals["matmul_i8_dequant_ms"]), 3),
            "matmul_i8_native_gflops":
                round(gflops(vals["matmul_i8_native_ms"]), 3),
            "attention_fwd_ms": round(vals["attention_fwd_ms"], 4),
        },
        "precision_memory": old_kernels["precision_memory"],
        "precision_comm": old_kernels["precision_comm"],
    }

    quant_rows = []
    for row in old_infer["quantized_base"]:
        spec, dt = row["spec"], row["frozen_base"]
        key = {"bf16": "bf16", "int8": "i8"}[dt]
        new_row = {k: v for k, v in row.items()
                   if v is not None and not k.startswith("max_logit")}
        new_row["ms_per_tok"] = round(
            vals[f"decode_{spec}_{key}_q_ms"], 4)
        new_row["ms_per_tok_f32"] = round(
            vals[f"decode_{spec}_f32_q_ms"], 4)
        quant_rows.append(new_row)

    infer = {
        "schema": "switchlora-bench-v2",
        "bench": "bench_infer",
        "host": host,
        "threads": 1,
        "note": NOTE,
        "results": [],
        "tracked": {
            "decode_tiny_ms_per_tok":
                round(vals["decode_tiny_tracked_ms"], 4),
            "decode_s1m_ms_per_tok":
                round(vals["decode_s1m_tracked_ms"], 4),
        },
        "quantized_base": quant_rows,
    }

    for path, doc in [(kernels_path, kernels), (infer_path, infer)]:
        with open(path, "w", encoding="utf-8") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"wrote {path}")
    for k, v in sorted(vals.items()):
        print(f"  {k} = {v:.4f}")


if __name__ == "__main__":
    main()
