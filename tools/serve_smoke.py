#!/usr/bin/env python3
"""End-to-end smoke test of `switchlora serve` (stdlib only).

Drives the real binary over real sockets and asserts the serving
contracts that matter in deployment:

  1. startup handshake: one machine-readable ``{"serve_ready": ...}``
     stdout line announces the bound port (``--port 0`` friendly);
  2. HTTP/1.1 keep-alive: several sequential requests reuse ONE
     connection (``Connection: keep-alive`` promised and honored), and
     an explicit ``Connection: close`` gets an EOF right after the
     response;
  3. multi-tenant continuous batching: a request for adapter ``b``
     issued *after* a long-running request for adapter ``a`` has started
     streaming must run alongside it and finish while ``a`` is still
     mid-stream — proving mid-flight batch join AND that tokens arrive
     incrementally (not buffered until completion);
  4. chunked prefill: a long-prompt request submitted mid-stream (the
     server runs with ``--prefill-chunk 16``) must NOT stall its peer —
     tokens for ``a`` keep arriving on the wire between prefill chunks,
     before the long request's first token;
  5. prefix cache: two requests for the same tenant sharing a
     256-token prefix — the second must splice the first's sealed KV
     blocks (``/healthz`` reports ``prefix_cache.hit_blocks > 0``) and
     prefill strictly fewer tokens (the ``prefilled_tokens`` delta);
  6. graceful drain: SIGTERM while a request is in flight lets that
     request stream to completion, then the process exits 0.

Usage:  python3 tools/serve_smoke.py [--bin target/release/switchlora]
"""

import argparse
import json
import os
import select
import signal
import socket
import subprocess
import sys
import time


def fail(msg):
    print("serve_smoke: FAIL: %s" % msg, file=sys.stderr)
    sys.exit(1)


class Stream:
    """One streaming POST: parses the response head, then yields the
    server's chunked-transfer payloads (one NDJSON line each) as the
    server flushes them."""

    def __init__(self, port, path, body):
        self.sock = socket.create_connection(("127.0.0.1", port),
                                             timeout=120)
        payload = json.dumps(body)
        req = ("POST %s HTTP/1.1\r\nHost: smoke\r\n"
               "Content-Type: application/json\r\n"
               "Content-Length: %d\r\n\r\n%s" % (path, len(payload),
                                                 payload))
        self.sock.sendall(req.encode())
        self.buf = b""
        head = self._read_until(b"\r\n\r\n")
        self.status = int(head.split()[1])
        self.head = head.decode("latin-1")
        self.done_line = None

    def _read_until(self, tok):
        while tok not in self.buf:
            d = self.sock.recv(4096)
            if not d:
                fail("connection closed mid-stream (buffered: %r)"
                     % self.buf[:200])
            self.buf += d
        i = self.buf.index(tok) + len(tok)
        out, self.buf = self.buf[:i], self.buf[i:]
        return out

    def next_event(self):
        """The next parsed NDJSON object, or None at end of stream."""
        size_line = self._read_until(b"\r\n")
        size = int(size_line.strip().split(b";")[0], 16)
        if size == 0:
            return None
        while len(self.buf) < size + 2:
            d = self.sock.recv(4096)
            if not d:
                fail("connection closed inside a chunk")
            self.buf += d
        data, self.buf = self.buf[:size], self.buf[size + 2:]
        return json.loads(data.decode())

    def finished(self):
        return self.done_line is not None

    def assert_still_streaming(self):
        """Non-blocking: slurp whatever the server has sent so far and
        assert the stream has NOT reached its terminal chunk.  The
        chunked terminator ``\\r\\n0\\r\\n\\r\\n`` cannot occur inside a
        JSON payload (no CR in JSON lines), so its absence means the
        server is still generating."""
        self.sock.setblocking(False)
        try:
            while True:
                d = self.sock.recv(65536)
                if not d:
                    fail("stream socket closed while peers were "
                         "still running")
                self.buf += d
        except BlockingIOError:
            pass
        finally:
            self.sock.settimeout(120)
        if b"\r\n0\r\n\r\n" in self.buf:
            fail("long request had already fully completed: tokens "
                 "were buffered, not streamed incrementally")

    def next_token(self):
        """Advance one event; returns a token id, or None once done."""
        if self.finished():
            return None
        ev = self.next_event()
        if ev is None:
            fail("stream terminated without a done line")
        if "error" in ev:
            fail("server error event: %s" % ev["error"])
        if ev.get("done"):
            self.done_line = ev
            return None
        return ev["token"]

    def drain(self):
        """Read to completion; returns (token_count, done_line)."""
        n = 0
        while self.next_token() is not None:
            n += 1
        return n, self.done_line


def get_json(port, path):
    s = socket.create_connection(("127.0.0.1", port), timeout=30)
    s.sendall(("GET %s HTTP/1.1\r\nHost: smoke\r\n"
               "Connection: close\r\n\r\n" % path).encode())
    data = b""
    while True:
        d = s.recv(4096)
        if not d:
            break
        data += d
    head, _, body = data.partition(b"\r\n\r\n")
    return int(head.split()[1]), json.loads(body.decode())


def read_one_response(sock, buf):
    """Read exactly one response off a kept-alive socket; returns
    (status, head text, body bytes, leftover buffer)."""
    while b"\r\n\r\n" not in buf:
        d = sock.recv(4096)
        if not d:
            fail("EOF inside a kept-alive response head")
        buf += d
    head, _, buf = buf.partition(b"\r\n\r\n")
    status = int(head.split()[1])
    headtext = head.decode("latin-1")
    lower = headtext.lower()
    body = b""
    for line in lower.split("\r\n"):
        if line.startswith("content-length:"):
            n = int(line.split(":", 1)[1])
            while len(buf) < n:
                d = sock.recv(4096)
                if not d:
                    fail("EOF inside a kept-alive response body")
                buf += d
            body, buf = buf[:n], buf[n:]
            break
    return status, headtext, body, buf


def keepalive_check(port):
    """Several sequential requests over ONE socket, then an explicit
    Connection: close that must be answered with an EOF."""
    s = socket.create_connection(("127.0.0.1", port), timeout=30)
    buf = b""
    for path in ("/healthz", "/v1/adapters", "/healthz"):
        s.sendall(("GET %s HTTP/1.1\r\nHost: smoke\r\n\r\n"
                   % path).encode())
        status, head, body, buf = read_one_response(s, buf)
        assert status == 200, head
        assert "connection: keep-alive" in head.lower(), head
        json.loads(body.decode())
    s.sendall(b"GET /healthz HTTP/1.1\r\nHost: smoke\r\n"
              b"Connection: close\r\n\r\n")
    status, head, body, buf = read_one_response(s, buf)
    assert status == 200, head
    assert "connection: close" in head.lower(), head
    assert buf == b"", "bytes after a Connection: close response"
    if s.recv(4096) != b"":
        fail("server kept the socket open after Connection: close")
    s.close()
    print("serve_smoke: keep-alive reused one connection for 3 "
          "requests; Connection: close honored with EOF")


def wait_ready(proc, timeout=300):
    deadline = time.time() + timeout
    while time.time() < deadline:
        line = proc.stdout.readline()
        if not line:
            fail("server exited before serve_ready (rc=%s)"
                 % proc.poll())
        line = line.strip()
        if not line:
            continue
        try:
            j = json.loads(line)
        except ValueError:
            continue
        if "serve_ready" in j:
            return int(j["serve_ready"]["port"])
    fail("timed out waiting for the serve_ready line")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bin", default=os.path.join(
        "target", "release", "switchlora"))
    args = ap.parse_args()
    if not os.path.exists(args.bin):
        print("serve_smoke: building %s" % args.bin, file=sys.stderr)
        subprocess.check_call(["cargo", "build", "--release"])
    # the binary directly, NOT `cargo run`: SIGTERM must reach the
    # server process itself for the drain assertion
    proc = subprocess.Popen(
        [args.bin, "serve", "--spec", "tiny",
         "--adapter", "a=seed:7", "--adapter", "b=seed:9",
         "--host", "127.0.0.1", "--port", "0",
         "--max-batch", "2", "--queue-depth", "8",
         "--max-context", "512", "--prefill-chunk", "16"],
        stdout=subprocess.PIPE, text=True)
    try:
        port = wait_ready(proc)
        print("serve_smoke: ready on port %d" % port)

        status, health = get_json(port, "/healthz")
        assert status == 200 and health["ok"] is True, health
        assert health["adapters"] == ["a", "b"], health
        status, ads = get_json(port, "/v1/adapters")
        assert status == 200 and len(ads) == 2, ads

        keepalive_check(port)

        # long request for tenant a: 200 tokens, streamed
        a = Stream(port, "/v1/generate",
                   {"prompt": "hello world", "adapter": "a",
                    "max_new": 200, "seed": 3})
        assert a.status == 200, a.head
        assert "chunked" in a.head.lower(), a.head
        first = a.next_token()
        assert first is not None, "no first token"
        print("serve_smoke: request a streaming (first token %d)"
              % first)

        # issued AFTER a's stream began; must join the running batch
        # and finish while a (200 tokens) is still going — this can
        # only happen if tokens stream incrementally and the batch is
        # continuous
        b = Stream(port, "/v1/generate",
                   {"prompt": "hi", "adapter": "b", "max_new": 16,
                    "seed": 4})
        assert b.status == 200, b.head
        nb, bdone = b.drain()
        assert nb == 16 and bdone["finish"] == "length", (nb, bdone)
        # at the moment b's done line arrived, a (200 tokens) must
        # still be mid-stream — sequential (non-batched) serving or
        # buffer-until-complete streaming would both have finished it
        a.assert_still_streaming()
        print("serve_smoke: request b joined mid-flight and finished "
              "(16 tokens) while a still streaming")

        # a LONG prompt (400 tokens = 25 prefill chunks of 16) joins
        # while a is still streaming.  With chunked prefill the
        # scheduler emits one decode token for a between chunks, so a's
        # tokens must keep arriving on the wire BEFORE d's first token;
        # monolithic prefill would stall a for the whole prompt.
        d = Stream(port, "/v1/generate",
                   {"prompt": "x" * 400, "adapter": "b", "max_new": 8,
                    "seed": 6})
        assert d.status == 200, d.head
        a_between = 0
        d_first = None
        a_live = True
        while d_first is None:
            if a_live and a.buf:
                t = a.next_token()
                if t is None:
                    a_live = False
                else:
                    a_between += 1
                continue
            rd, _, _ = select.select(
                [a.sock, d.sock] if a_live else [d.sock], [], [], 120)
            if not rd:
                fail("timed out waiting for interleaved streams")
            if d.sock in rd:
                d_first = d.next_token()
                if d_first is None:
                    fail("long request finished before its first token")
            elif a.sock in rd:
                t = a.next_token()
                if t is None:
                    a_live = False
                else:
                    a_between += 1
        assert a_between >= 3, (
            "peer starved during a 25-chunk prefill: only %d tokens "
            "arrived before the long request's first token" % a_between)
        nd, ddone = d.drain()
        assert nd == 8 and ddone["finish"] == "length", (nd, ddone)
        print("serve_smoke: long 400-token prompt prefilled in chunks; "
              "%d peer tokens streamed between chunks" % a_between)

        na, adone = a.drain()
        assert na == 200 and adone["finish"] == "length", (na, adone)
        assert adone["n_generated"] == 200, adone

        # prefix cache: two same-tenant requests sharing a 256-token
        # prefix (8 whole 32-position KV blocks) with distinct tails.
        # The second must splice the first's sealed blocks and prefill
        # only the uncached suffix.
        pfx = [(3 * i + 11) % 200 for i in range(256)]
        _, h0 = get_json(port, "/healthz")
        assert h0["prefix_cache"]["enabled"] is True, h0
        w1 = Stream(port, "/v1/generate",
                    {"tokens": pfx + [201, 202], "adapter": "a",
                     "max_new": 4, "seed": 8})
        assert w1.status == 200, w1.head
        w1.drain()
        _, h1 = get_json(port, "/healthz")
        w2 = Stream(port, "/v1/generate",
                    {"tokens": pfx + [203, 204], "adapter": "a",
                     "max_new": 4, "seed": 9})
        assert w2.status == 200, w2.head
        w2.drain()
        cold_prefilled = h1["prefilled_tokens"] - h0["prefilled_tokens"]
        # the scheduler mirrors prefix counters into /healthz each loop
        # tick; poll briefly rather than racing it
        deadline = time.time() + 5
        while True:
            _, h2 = get_json(port, "/healthz")
            warm_prefilled = (h2["prefilled_tokens"]
                              - h1["prefilled_tokens"])
            hit_blocks = (h2["prefix_cache"]["hit_blocks"]
                          - h0["prefix_cache"]["hit_blocks"])
            if (warm_prefilled > 0 and hit_blocks > 0) \
                    or time.time() > deadline:
                break
            time.sleep(0.05)
        assert hit_blocks > 0, (
            "identical 256-token prefixes never hit the prefix cache: "
            "%r" % h2["prefix_cache"])
        assert 0 < warm_prefilled < cold_prefilled, (
            "warm request should prefill only the uncached suffix "
            "(%d vs %d tokens)" % (warm_prefilled, cold_prefilled))
        print("serve_smoke: prefix cache hit %d blocks; warm request "
              "prefilled %d tokens vs %d cold"
              % (hit_blocks, warm_prefilled, cold_prefilled))

        # graceful drain: SIGTERM mid-request; the in-flight request
        # must still stream to completion and the process must exit 0
        c = Stream(port, "/v1/generate",
                   {"prompt": "drain me", "max_new": 300, "seed": 5})
        assert c.status == 200, c.head
        assert c.next_token() is not None, "no token before SIGTERM"
        proc.send_signal(signal.SIGTERM)
        print("serve_smoke: SIGTERM sent mid-request")
        nc, cdone = c.drain()
        assert nc == 300 and cdone["finish"] == "length", (nc, cdone)
        rc = proc.wait(timeout=120)
        assert rc == 0, "server exited %d after drain" % rc
        print("serve_smoke: OK — keep-alive reuse, mid-flight join, "
              "chunked prefill interleaving, prefix-cache sharing, "
              "graceful drain")
    except Exception:
        proc.kill()
        raise
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()


if __name__ == "__main__":
    main()
