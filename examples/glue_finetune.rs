//! Tables 7/8 analog: full fine-tuning of pre-trained checkpoints on the
//! GLUE-analog task suite (see `data/tasks.rs` for the task↔GLUE mapping).
//!
//! Protocol per the paper's Section 4.4: pre-train with full-rank /
//! SwitchLoRA / GaLore; merge SwitchLoRA adapters into the base weights;
//! full fine-tune each resulting model per task; report accuracy and the
//! per-method average.
//!
//! ```bash
//! cargo run --release --example glue_finetune -- \
//!     [--spec s1m] [--pretrain-steps 400] [--ft-steps 250]
//! ```

use anyhow::Result;

use switchlora::cli::Args;
use switchlora::coordinator::trainer::{Method, TrainConfig};
use switchlora::data::tasks::Task;
use switchlora::exp;
use switchlora::model::layout::{Manifest, Variant};
use switchlora::runtime::Engine;

fn main() -> Result<()> {
    switchlora::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let spec = args.get_or("spec", "s1m");
    let pretrain_steps = args.parse_num("pretrain-steps", 400u64)?;
    let ft_steps = args.parse_num("ft-steps", 250u64)?;
    let seed = args.parse_num("seed", 42u64)?;
    let mut engine = Engine::cpu()?;
    let man = Manifest::for_spec(
        &switchlora::coordinator::trainer::default_artifacts_dir(),
        &spec)?;

    let arms: Vec<(&str, Method, Variant, f32)> = vec![
        // fine-tune lr per arm follows the paper's Table 10 pattern:
        // SwitchLoRA-pretrained tolerates a slightly higher ft lr.
        ("full-rank", Method::full(), Variant::Full, 1e-3),
        ("switchlora", Method::parse("switchlora").unwrap(), Variant::Lora,
         2e-3),
        ("galore", Method::parse("galore").unwrap(), Variant::Full, 1e-3),
    ];
    let tasks = Task::ALL;

    let mut table: Vec<(String, f64, Vec<f32>)> = Vec::new();
    for (label, method, variant, ft_lr) in arms {
        let mut cfg = TrainConfig::new(&spec, method, pretrain_steps);
        cfg.seed = seed;
        let (res, store) = exp::pretrain(&mut engine, cfg)?;
        switchlora::info!("{label}: pretrain ppl {:.2}", res.final_ppl);
        let results = exp::finetune::glue_suite(
            &mut engine, &man, &store, variant, &tasks, ft_steps, ft_lr,
            seed)?;
        let accs: Vec<f32> = results.iter().map(|r| r.accuracy).collect();
        table.push((label.to_string(), res.final_ppl, accs));
    }

    // ---- Table 7/8 analog ----
    print!("\n== GLUE-analog full fine-tuning ({spec}) ==\n{:<12} {:>8}",
           "method", "ppl");
    for t in tasks {
        print!(" {:>9}", t.name());
    }
    println!(" {:>8}", "avg");
    for (label, ppl, accs) in &table {
        print!("{label:<12} {ppl:>8.2}");
        for a in accs {
            print!(" {:>9.3}", a);
        }
        let avg = accs.iter().sum::<f32>() / accs.len() as f32;
        println!(" {avg:>8.3}");
    }
    let avg_of = |l: &str| {
        table.iter().find(|(x, _, _)| x == l)
            .map(|(_, _, a)| a.iter().sum::<f32>() / a.len() as f32)
            .unwrap_or(f32::NAN)
    };
    println!("\nswitchlora avg − full avg = {:+.3} (paper: +0.003..+0.01); \
              switchlora avg − galore avg = {:+.3} (paper: ≈+0.03)",
             avg_of("switchlora") - avg_of("full-rank"),
             avg_of("switchlora") - avg_of("galore"));
    Ok(())
}
