//! Appendix B ablations (Figures 6, 7, 8, 9) on the 130M-analog.
//!
//! Sub-experiments (pick with the first positional arg; default `all`):
//! * `freq`   — Figure 6/7: interval₀ × decay-ratio grid.  Moderate values
//!              should win; extreme frequencies degrade.
//! * `frozen` — Figure 8: frozen-steps N sweep (too small ⇒ momentum
//!              shock, too large ⇒ data bias).
//! * `init`   — Figure 9: the paper's Eq. (3) init vs LoRA-default init
//!              under SwitchLoRA training.
//!
//! ```bash
//! cargo run --release --example ablations -- [all|freq|frozen|init] \
//!     [--spec tiny] [--steps 250]
//! ```

use anyhow::Result;

use switchlora::cli::Args;
use switchlora::coordinator::trainer::{Method, TrainConfig};
use switchlora::methods::SwitchParams;
use switchlora::exp;
use switchlora::model::init::InitMode;
use switchlora::runtime::Engine;

struct Row {
    label: String,
    eval: f64,
    ppl: f64,
}

fn run(engine: &mut Engine, spec: &str, steps: u64, label: &str,
       p: SwitchParams, init: InitMode) -> Result<Row> {
    let mut cfg = TrainConfig::new(spec, Method::switchlora(p), steps);
    cfg.init = init;
    cfg.metrics_csv = Some(
        format!("results/ablation_{spec}_{label}.csv").into());
    let (res, _) = exp::pretrain(engine, cfg)?;
    Ok(Row { label: label.to_string(), eval: res.final_eval_loss,
             ppl: res.final_ppl })
}

fn print_rows(title: &str, rows: &[Row]) {
    println!("\n== {title} ==");
    println!("{:<28} {:>10} {:>8}", "setting", "eval_loss", "ppl");
    for r in rows {
        println!("{:<28} {:>10.4} {:>8.2}", r.label, r.eval, r.ppl);
    }
    if let Some(best) = rows.iter().min_by(|a, b|
        a.eval.partial_cmp(&b.eval).unwrap()) {
        println!("best: {}", best.label);
    }
}

fn main() -> Result<()> {
    switchlora::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let spec = args.get_or("spec", "tiny");
    let steps = args.parse_num("steps", 250u64)?;
    let mut engine = Engine::cpu()?;

    if which == "freq" || which == "all" {
        // Figure 6/7: interval0 × ratio grid (paper sweeps both)
        let mut rows = Vec::new();
        for interval0 in [5.0, 40.0, 320.0] {
            for ratio in [0.025, 0.1, 0.4] {
                rows.push(run(
                    &mut engine, &spec, steps,
                    &format!("freq_i{interval0}_r{ratio}"),
                    SwitchParams { interval0, ratio, n_freeze: 5 },
                    InitMode::SwitchLora)?);
            }
        }
        print_rows("Figure 6/7 analog: switching frequency grid", &rows);
    }

    if which == "frozen" || which == "all" {
        // Figure 8: N sweep
        let mut rows = Vec::new();
        for n in [0u64, 2, 5, 15, 40] {
            rows.push(run(&mut engine, &spec, steps, &format!("frozen_N{n}"),
                          SwitchParams { n_freeze: n,
                                         ..SwitchParams::default() },
                          InitMode::SwitchLora)?);
        }
        print_rows("Figure 8 analog: frozen steps N", &rows);
    }

    if which == "init" || which == "all" {
        // Figure 9: init rule
        let rows = vec![
            run(&mut engine, &spec, steps, "init_switchlora",
                SwitchParams::default(), InitMode::SwitchLora)?,
            run(&mut engine, &spec, steps, "init_lora_default",
                SwitchParams::default(), InitMode::LoraDefault)?,
        ];
        print_rows("Figure 9 analog: initialization rule", &rows);
        if rows[0].eval < rows[1].eval {
            println!("Eq.(3) init beats LoRA-default init \
                      (paper's Figure 9 finding)");
        }
    }
    Ok(())
}
