//! Figure 2 / Figure 3 + Table 2 / Table 3 analog: full-rank vs LoRA vs
//! SwitchLoRA across model sizes and LoRA ranks.
//!
//! The paper's claims under test (at testbed scale):
//!   1. plain LoRA pre-training trails full-rank badly;
//!   2. SwitchLoRA closes most of the gap at the same rank;
//!   3. a higher rank closes it further (Fig. 3 / Table 3).
//!
//! ```bash
//! cargo run --release --example compare_methods -- \
//!     [--specs tiny,s1m] [--steps 400] [--high-rank]
//! ```
//! Loss curves land in `results/<spec>_<method>.csv`.

use anyhow::Result;

use switchlora::cli::{csv_list, Args};
use switchlora::coordinator::trainer::Method;
use switchlora::exp;
use switchlora::runtime::Engine;

/// The higher-rank artifact spec for a base spec (rank h/4 → h/2).
fn high_rank_spec(spec: &str) -> Option<&'static str> {
    match spec {
        "tiny" => Some("tiny_r32"),
        "s1m" => Some("s1m_r64"),
        "s4m" => Some("s4m_r128"),
        _ => None,
    }
}

fn main() -> Result<()> {
    switchlora::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let specs = csv_list(&args.get_or("specs", "tiny,s1m"));
    let steps = args.parse_num("steps", 400u64)?;
    let out = std::path::PathBuf::from("results");
    let mut engine = Engine::cpu()?;

    let mut all = Vec::new();
    for spec in &specs {
        let methods = [
            Method::full(),
            Method::lora(),
            Method::parse("switchlora").unwrap(),
        ];
        let mut rows = exp::compare_methods(&mut engine, spec, steps,
                                            &methods, &out, 1)?;
        // Fig. 3: SwitchLoRA again at double rank, if artifacts exist
        if args.flag("high-rank") {
            if let Some(hr) = high_rank_spec(spec) {
                if switchlora::cli::check_spec(
                    &switchlora::coordinator::trainer::
                        default_artifacts_dir(), hr).is_ok() {
                    rows.extend(exp::compare_methods(
                        &mut engine, hr, steps,
                        &[Method::parse("switchlora").unwrap()], &out, 1)?);
                }
            }
        }
        print!("{}", exp::results_table(
            &format!("Table 2/3 analog — {spec}"), &rows));
        // the paper's ordering
        let get = |m: &str| rows.iter().find(|r| r.method == m)
            .map(|r| r.final_eval_loss);
        if let (Some(f), Some(l), Some(s)) =
            (get("full"), get("lora"),
             rows.iter().find(|r| r.method == "switchlora")
                 .map(|r| r.final_eval_loss)) {
            println!("ordering: lora {l:.4} > switchlora {s:.4} ≈ full \
                      {f:.4}  (gap closed: {:.0}%)",
                     100.0 * (l - s) / (l - f).max(1e-9));
        }
        all.extend(rows);
    }
    print!("{}", exp::results_table("all runs", &all));
    Ok(())
}
