//! Figures 10/11 + Appendix E analog: singular-value spectra of trained
//! effective weights per layer type, for LoRA vs SwitchLoRA vs full-rank.
//!
//! Paper's finding: plain-LoRA training leaves weight spectra "ill" —
//! singular values converge in a narrow band because all updates live in
//! the rank-r adapter — while SwitchLoRA's spectra track full-rank
//! training's.  We quantify that with s_max/s_med (spread) and effective
//! rank at 1% of s_max.
//!
//! ```bash
//! cargo run --release --example rank_analysis -- \
//!     [--spec tiny] [--steps 300]
//! ```

use anyhow::Result;

use switchlora::cli::Args;
use switchlora::coordinator::trainer::{Method, TrainConfig};
use switchlora::exp;
use switchlora::exp::rank::{analyze, table};
use switchlora::model::layout::{Manifest, Variant};
use switchlora::runtime::Engine;

fn main() -> Result<()> {
    switchlora::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let spec = args.get_or("spec", "tiny");
    let steps = args.parse_num("steps", 300u64)?;
    let mut engine = Engine::cpu()?;
    let man = Manifest::for_spec(
        &switchlora::coordinator::trainer::default_artifacts_dir(),
        &spec)?;

    let mut spreads = Vec::new();
    for (method, variant) in [
        (Method::full(), Variant::Full),
        (Method::lora(), Variant::Lora),
        (Method::parse("switchlora").unwrap(), Variant::Lora),
    ] {
        let name = method.name().to_string();
        let cfg = TrainConfig::new(&spec, method, steps);
        let (res, store) = exp::pretrain(&mut engine, cfg)?;
        let rows = analyze(&store, &man, variant)?;
        println!("\n== {} (eval ppl {:.2}) ==", name, res.final_ppl);
        print!("{}", table(&rows));
        let mean_cond: f64 = rows.iter().map(|r| r.condition).sum::<f64>()
            / rows.len() as f64;
        let mean_eff: f64 = rows.iter().map(|r| r.eff_rank_frac)
            .sum::<f64>() / rows.len() as f64;
        spreads.push((name, mean_cond, mean_eff));
    }

    println!("\n== Figure 10/11 summary ==");
    println!("{:<12} {:>14} {:>12}", "method", "s_max/s_med", "eff_rank%");
    for (name, cond, eff) in &spreads {
        println!("{name:<12} {cond:>14.2} {:>12.1}", 100.0 * eff);
    }
    let get = |n: &str| spreads.iter().find(|(x, _, _)| x == n).cloned();
    if let (Some(f), Some(l), Some(s)) =
        (get("full"), get("lora"), get("switchlora")) {
        println!("\nspectral spread: |switchlora − full| = {:.2}, \
                  |lora − full| = {:.2} → {}",
                 (s.1 - f.1).abs(), (l.1 - f.1).abs(),
                 if (s.1 - f.1).abs() <= (l.1 - f.1).abs() {
                     "SwitchLoRA's spectrum tracks full-rank more closely \
                      (Fig. 11)"
                 } else {
                     "ordering NOT reproduced at this scale"
                 });
    }
    Ok(())
}
