//! Generation demo: load a checkpoint (or seed a random init), run
//! KV-cached batched generation with greedy and sampled decoding, and
//! show the adapter-merge deployment path producing identical greedy
//! output with zero adapter overhead.
//!
//! ```bash
//! cargo run --release --example generate -- \
//!     [--spec tiny] [--ckpt results/quickstart.ckpt] [--max-new 48]
//! ```

use std::time::Instant;

use anyhow::Result;

use switchlora::cli::Args;
use switchlora::coordinator::checkpoint;
use switchlora::data::tokenizer::{ByteTokenizer, Tokenizer};
use switchlora::infer::{generate, merged_full_store, GenConfig, Sampler};
use switchlora::model::init::seeded_store;
use switchlora::model::layout::{Manifest, Variant};
use switchlora::runtime::NativeModel;
use switchlora::util::printable;

fn main() -> Result<()> {
    switchlora::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let spec = args.get_or("spec", "tiny");
    let max_new = args.parse_num("max-new", 48usize)?;
    let manifest = Manifest::for_spec(
        &switchlora::coordinator::trainer::default_artifacts_dir(), &spec)?;
    let mc = manifest.config.clone();

    let mut store = seeded_store(&manifest, Variant::Lora, 0)?;
    if let Some(ckpt) = args.get("ckpt") {
        let ck = checkpoint::load(std::path::Path::new(ckpt))?;
        let rep = ck.restore_into(&mut store);
        println!("checkpoint {ckpt}: {} params loaded, {} skipped",
                 rep.loaded, rep.missing + rep.mismatched);
    } else {
        println!("no --ckpt: generating from a seeded random init \
                  (train one with `cargo run --example quickstart`)");
    }

    let model = NativeModel::new(manifest.clone(), Variant::Lora)?;
    let tok = ByteTokenizer::new(mc.vocab);
    let prompts: Vec<Vec<i32>> = ["The switch", "Low-rank ada", "Full-rank"]
        .iter()
        .map(|p| tok.encode(p))
        .collect();

    // ---- batched greedy decode on the LoRA store ----
    let cfg = GenConfig::greedy(max_new);
    let t0 = Instant::now();
    let out = generate(&model, &store, &prompts, &cfg)?;
    let dt = t0.elapsed().as_secs_f64();
    println!("\n== greedy, unmerged LoRA ({} sequences) ==", prompts.len());
    for (s, seq) in out.sequences.iter().enumerate() {
        println!("  [{s}] {:?}", printable(&tok.decode(&seq[..])));
    }
    let total: usize = out.n_generated.iter().sum();
    println!("  prefill {} tok, {} decode steps, {:.1} tok/s",
             out.prefill_tokens, out.decode_steps,
             total as f64 / dt.max(1e-9));

    // ---- merged deployment path: same function, dense-only decode ----
    let merged = merged_full_store(&manifest, &store)?;
    let dense = NativeModel::new(manifest.clone(), Variant::Full)?;
    let t1 = Instant::now();
    let out_m = generate(&dense, &merged, &prompts, &cfg)?;
    let dt_m = t1.elapsed().as_secs_f64();
    // the in-place merge (adapters folded, B zeroed) computes the exact
    // same dense weights as the export, so its streams must be identical
    let mut inplace = store.clone();
    switchlora::infer::merge_adapters(&mut inplace, &manifest)?;
    let out_i = generate(&model, &inplace, &prompts, &cfg)?;
    assert_eq!(out_m.sequences, out_i.sequences,
               "export and in-place merge must agree exactly");
    let agree = out
        .sequences
        .iter()
        .zip(&out_m.sequences)
        .filter(|(a, b)| a == b)
        .count();
    println!("\n== greedy, merged W + s·B·A (zero adapter overhead) ==");
    println!("  export == in-place merge ✓   unmerged streams matched \
              {agree}/{} (argmax near-ties may flip under float \
              reassociation)", prompts.len());
    println!("  merged {:.1} tok/s   unmerged {:.1} tok/s",
             total as f64 / dt_m.max(1e-9), total as f64 / dt.max(1e-9));

    // ---- sampled decode: top-k + temperature, seeded ----
    let cfg_s = GenConfig {
        max_new,
        sampler: Sampler::top_k(32, 0.9),
        stop_tokens: vec![0],
        seed: 7,
        max_context: None,
    };
    let out_s = generate(&model, &store, &prompts, &cfg_s)?;
    println!("\n== sampled (top-k 32, temperature 0.9, seed 7) ==");
    for (s, seq) in out_s.sequences.iter().enumerate() {
        println!("  [{s}] {} new tokens: {:?}", out_s.n_generated[s],
                 printable(&tok.decode(&seq[prompts[s].len()..])));
    }
    Ok(())
}

