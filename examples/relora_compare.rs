//! Figure 4 analog: ReLoRA vs SwitchLoRA under full-rank warm starts.
//!
//! The paper shows (a) ReLoRA needs a long full-rank warm start (5000
//! steps) to be competitive, while SwitchLoRA needs almost none (200),
//! and (b) at an equal warm start (1000) SwitchLoRA wins clearly; ReLoRA's
//! loss drops abruptly at each coarse reset while SwitchLoRA's decreases
//! smoothly.  Scaled here to the testbed: total/warm-start steps divided
//! by ~8, same ratios.
//!
//! ```bash
//! cargo run --release --example relora_compare -- \
//!     [--spec s1m] [--steps 600]
//! ```

use anyhow::Result;

use switchlora::cli::Args;
use switchlora::coordinator::trainer::{Method, TrainConfig};
use switchlora::methods::{ReLoraParams, SwitchParams};
use switchlora::exp;
use switchlora::runtime::Engine;

fn main() -> Result<()> {
    switchlora::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let spec = args.get_or("spec", "s1m");
    let steps = args.parse_num("steps", 600u64)?;
    let mut engine = Engine::cpu()?;
    let mut rows = Vec::new();

    // (method label, method, full-warm-start steps) — the paper's panels:
    // left: ReLoRA warm 5000 vs SwitchLoRA warm 200 (25:1 ratio);
    // right: both warm 1000.
    let reset = (steps / 4).max(10); // ReLoRA resets 1/4 of total, as paper
    let runs: Vec<(String, Method, u64)> = vec![
        ("relora_warmL".into(),
         Method::relora(ReLoraParams { reset_interval: reset, rewarm: 20 }),
         steps / 4),
        ("switchlora_warmS".into(),
         Method::switchlora(SwitchParams::default()), steps / 100),
        ("relora_warmE".into(),
         Method::relora(ReLoraParams { reset_interval: reset, rewarm: 20 }),
         steps / 20),
        ("switchlora_warmE".into(),
         Method::switchlora(SwitchParams::default()), steps / 20),
    ];
    for (label, method, warm) in runs {
        let mut cfg = TrainConfig::new(&spec, method, steps);
        cfg.full_warmup_steps = warm;
        cfg.metrics_csv =
            Some(format!("results/fig4_{spec}_{label}.csv").into());
        let (res, _) = exp::pretrain(&mut engine, cfg)?;
        println!("{label:<20} warm {warm:>4}  eval {:.4}  ppl {:.2}",
                 res.final_eval_loss, res.final_ppl);
        rows.push((label, warm, res));
    }

    println!("\n== Figure 4 analog ({spec}, {steps} steps) ==");
    println!("{:<20} {:>6} {:>10} {:>8}", "run", "warm", "eval_loss",
             "ppl");
    for (label, warm, r) in &rows {
        println!("{label:<20} {warm:>6} {:>10.4} {:>8.2}",
                 r.final_eval_loss, r.final_ppl);
    }
    // headline check: SwitchLoRA with tiny warm start beats ReLoRA with a
    // far longer one
    let get = |l: &str| rows.iter().find(|(x, _, _)| x == l)
        .map(|(_, _, r)| r.final_eval_loss).unwrap_or(f64::NAN);
    println!("\nswitchlora (warm {}) vs relora (warm {}): {:.4} vs {:.4} \
              → {}",
             steps / 100, steps / 4, get("switchlora_warmS"),
             get("relora_warmL"),
             if get("switchlora_warmS") < get("relora_warmL") {
                 "SwitchLoRA wins with 25x less full-rank warm-up \
                  (paper's Fig. 4 left)"
             } else {
                 "ordering NOT reproduced at this scale"
             });
    Ok(())
}
