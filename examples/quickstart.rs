//! Quickstart: pre-train a tiny LLaMA with SwitchLoRA, evaluate, and save
//! a checkpoint.  Runs on the native CPU engine out of the box; with
//! `--features pjrt` + AOT artifacts it drives the PJRT/HLO path instead.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;

use switchlora::cli::Args;
use switchlora::coordinator::checkpoint;
use switchlora::coordinator::trainer::{Method, TrainConfig};
use switchlora::methods::SwitchParams;
use switchlora::exp;
use switchlora::runtime::Engine;
use switchlora::util::human_bytes;

fn main() -> Result<()> {
    switchlora::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let spec = args.get_or("spec", "tiny");
    let steps = args.parse_num("steps", 150u64)?;

    let mut cfg = TrainConfig::new(
        &spec,
        Method::switchlora(SwitchParams::default()),
        steps,
    );
    cfg.metrics_csv = Some("results/quickstart.csv".into());
    cfg.eval_every = (steps / 5).max(1);

    let mut engine = Engine::cpu()?;
    let (res, store) = exp::pretrain(&mut engine, cfg)?;

    print!("{}", exp::results_table("quickstart", &[res.clone()]));
    println!("switches performed: {}   candidate offload traffic: {}",
             res.counter("switches"),
             human_bytes(res.counter("offload_bytes")));
    println!("loss curve written to results/quickstart.csv");

    checkpoint::save(std::path::Path::new("results/quickstart.ckpt"),
                     &spec, &store, None)?;
    println!("checkpoint saved to results/quickstart.ckpt");
    Ok(())
}
