//! Table 6 analog: GaLore vs SwitchLoRA across rank / model size / seq len.
//!
//! Paper's Table 6 rows (350M standard, rank 256, seq 256) mapped to the
//! testbed: standard = s1m (rank 32 = h/4, seq 64); the sweep changes one
//! variable at a time exactly as the paper does:
//!
//! | paper cell        | here        |
//! |-------------------|-------------|
//! | standard          | s1m         |
//! | model size = 130M | tiny        |
//! | rank = 128 (÷2)   | s1m_r8      |
//! | rank = 32  (÷8)   | s1m_r4      |
//! | seq len = 512 (×2)| s1m_s128    |
//!
//! Claim under test: SwitchLoRA ≥ GaLore everywhere, with the gap widening
//! sharply at small rank (GaLore's SVD compresses away low-energy gradient
//! directions; SwitchLoRA keeps covering all of them).
//!
//! ```bash
//! cargo run --release --example galore_compare -- [--steps 300]
//! ```

use anyhow::Result;

use switchlora::cli::Args;
use switchlora::coordinator::trainer::{Method, TrainConfig};
use switchlora::methods::GaloreParams;
use switchlora::exp;
use switchlora::runtime::Engine;

fn main() -> Result<()> {
    switchlora::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.parse_num("steps", 300u64)?;
    let cells: Vec<(&str, &str)> = vec![
        ("standard", "s1m"),
        ("model=tiny", "tiny"),
        ("rank/4", "s1m_r8"),
        ("rank/8", "s1m_r4"),
        ("seq x2", "s1m_s128"),
    ];
    let mut engine = Engine::cpu()?;

    println!("{:<12} {:<10} {:>12} {:>12} {:>8}", "cell", "spec",
             "galore_ppl", "switch_ppl", "winner");
    let mut galore_wins = 0;
    let mut rows = Vec::new();
    for (cell, spec) in &cells {
        // GaLore: project to the spec's LoRA rank, refresh every 50 steps
        // (paper: 1/200 of 40k ≈ steps/200; at our scale steps/6 ≈ 50)
        let galore = Method::galore(GaloreParams {
            rank: 0,
            update_freq: (steps / 6).max(10),
            scale: 0.25,
        });
        let mut cfg_g = TrainConfig::new(spec, galore, steps);
        cfg_g.metrics_csv =
            Some(format!("results/table6_{spec}_galore.csv").into());
        let (g, _) = exp::pretrain(&mut engine, cfg_g)?;

        let mut cfg_s = TrainConfig::new(
            spec, Method::parse("switchlora").unwrap(), steps);
        cfg_s.metrics_csv =
            Some(format!("results/table6_{spec}_switchlora.csv").into());
        let (s, _) = exp::pretrain(&mut engine, cfg_s)?;

        let winner = if s.final_ppl <= g.final_ppl {
            "switchlora"
        } else {
            galore_wins += 1;
            "galore"
        };
        println!("{:<12} {:<10} {:>12.2} {:>12.2} {:>8}", cell, spec,
                 g.final_ppl, s.final_ppl, winner);
        rows.push((cell.to_string(), g, s));
    }
    // the paper's strongest claim is the small-rank cell
    if let Some((_, g, s)) = rows.iter().find(|(c, _, _)| c == "rank/8") {
        println!(
            "\nsmall-rank gap: galore ppl {:.2} vs switchlora {:.2} \
             (paper: 34.09 vs 25.26 — ratio {:.2} here vs 1.35 paper)",
            g.final_ppl, s.final_ppl, g.final_ppl / s.final_ppl);
    }
    println!("galore wins {galore_wins}/{} cells (paper: 0)", rows.len());
    Ok(())
}
