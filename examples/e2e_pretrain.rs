//! End-to-end system driver.
//!
//! Exercises every layer of the stack on a real small workload: pre-trains
//! the largest shipped config (`s8m`, ≈5.8M params — scaled for the
//! single-core CPU testbed) with SwitchLoRA under simulated
//! data parallelism, logging:
//!
//! * the training/eval loss curve (→ `results/e2e_<spec>_<method>.csv`),
//! * measured ring all-reduce traffic vs the Appendix F model,
//! * measured candidate-offload traffic vs the Appendix D formula,
//! * step-time breakdown,
//!
//! then saves the checkpoint and runs a fine-tuning probe on one task to
//! prove the pretrain → merge → finetune path composes.
//!
//! ```bash
//! cargo run --release --example e2e_pretrain -- \
//!     [--spec s8m] [--steps 300] [--workers 2] [--method switchlora]
//! ```

use anyhow::Result;

use switchlora::cli::Args;
use switchlora::coordinator::checkpoint;
use switchlora::coordinator::trainer::{Method, TrainConfig};
use switchlora::data::tasks::Task;
use switchlora::exp;
use switchlora::model::analytics;
use switchlora::model::layout::{Manifest, Variant};
use switchlora::runtime::Engine;
use switchlora::util::human_bytes;

fn main() -> Result<()> {
    switchlora::util::logging::init();
    let args = Args::parse(std::env::args().skip(1));
    let spec = args.get_or("spec", "s8m");
    let steps = args.parse_num("steps", 300u64)?;
    let workers = args.parse_num("workers", 2usize)?;
    let method = Method::parse(&args.get_or("method", "switchlora"))
        .expect("method");

    let mut cfg = TrainConfig::new(&spec, method, steps);
    cfg.workers = workers;
    cfg.eval_every = (steps / 10).max(1);
    cfg.metrics_csv = Some(
        format!("results/e2e_{spec}_{}.csv", cfg.method.name()).into());

    let mut engine = Engine::cpu()?;
    let (res, store) = exp::pretrain(&mut engine, cfg)?;
    print!("{}", exp::results_table("e2e pretrain", &[res.clone()]));

    // ---- systems accounting vs the analytic models ----
    let man = Manifest::for_spec(
        &switchlora::coordinator::trainer::default_artifacts_dir(),
        &spec)?;
    let measured_comm = res.comm.bytes as f64 / steps as f64;
    let model_comm = analytics::dp_comm_bytes_per_step(
        res.n_trainable as u64, workers as u64) as f64;
    println!("\nDP comm/step: measured {}  model {}  (ratio {:.3})",
             human_bytes(measured_comm as u64),
             human_bytes(model_comm as u64),
             measured_comm / model_comm.max(1.0));
    if res.counter("offload_bytes") > 0 {
        let measured_off = res.counter("offload_bytes") as f64
            / steps as f64;
        println!("offload/step: measured {}  (Appendix D formula scales \
                  with switch frequency; see bench_tables for the model)",
                 human_bytes(measured_off as u64));
    }
    println!("trainable: {} / full {}  (comm saving {:.1}%)",
             res.n_trainable, man.full.n_trainable,
             100.0 * (1.0 - res.n_trainable as f64
                      / man.full.n_trainable as f64));
    println!("mean step: {:.1} ms over {} steps ({} executable runs)",
             res.mean_step_ms, steps, workers + 1);

    // ---- checkpoint + fine-tune probe ----
    let ckpt = format!("results/e2e_{spec}.ckpt");
    checkpoint::save(std::path::Path::new(&ckpt), &spec, &store, None)?;
    println!("checkpoint: {ckpt}");
    if man.cls.is_some() {
        let ft = exp::finetune::glue_suite(
            &mut engine, &man, &store, Variant::Lora, &[Task::Majority],
            120, 2e-3, 7)?;
        println!("fine-tune probe (majority): acc {:.3}", ft[0].accuracy);
    } else {
        println!("(no cls artifacts for {spec}; fine-tune probe skipped)");
    }
    println!("\nE2E complete: loss {:.4} → {:.4} (ppl {:.2})",
             res.train_curve.first().map(|x| x.1).unwrap_or(f64::NAN),
             res.final_eval_loss, res.final_ppl);
    Ok(())
}
